//! HTTP/1.1 wire format (offline substitute for hyper/axum): message
//! framing over `TcpStream`, request/response views, and the small
//! client the load generator and tests drive real sockets with.
//!
//! Scope is deliberately the serving subset the frontend needs:
//! `Content-Length` framing only (no chunked transfer encoding), CRLF
//! header sections, persistent connections by default (HTTP/1.1
//! keep-alive) with `Connection: close` honoured.  Both sides of the
//! conversation — [`HttpConn`] under the server's connection handlers
//! and [`Client`] under the device fleet — share the same framing code.

use std::collections::BTreeMap;
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Value;

/// Cap on the header section of one message.
pub const MAX_HEAD_BYTES: usize = 64 * 1024;
/// Cap on one message body (a full-batch score request is ~100 KiB).
pub const MAX_BODY_BYTES: usize = 16 * 1024 * 1024;
/// Deadline for finishing a message whose first bytes have arrived
/// (slow-loris guard: a half-sent request cannot pin a worker forever).
const MID_MESSAGE_DEADLINE: Duration = Duration::from_secs(30);

/// One framed HTTP message: start line, headers (keys lower-cased),
/// body.  Requests and responses differ only in the start line.
#[derive(Debug)]
pub struct Message {
    pub start_line: String,
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
}

/// Result of one [`HttpConn::read_message`] call.
#[derive(Debug)]
pub enum Outcome {
    /// A complete message arrived.
    Message(Message),
    /// The peer closed the connection cleanly between messages.
    Closed,
    /// The socket read timed out this tick.  Any partial message stays
    /// buffered in the connection, so the caller can check its own
    /// conditions (shutdown flag, keep-alive budget) and simply call
    /// `read_message` again to resume.
    Idle,
}

/// A TCP connection with message framing and pipelining-safe buffering
/// (bytes past the current message are kept for the next read).
pub struct HttpConn {
    stream: TcpStream,
    buf: Vec<u8>,
    /// When the currently-buffered (incomplete) message started
    /// arriving — the slow-loris deadline baseline, surviving across
    /// `read_message` calls that return [`Outcome::Idle`].
    msg_started: Option<Instant>,
}

impl HttpConn {
    pub fn new(stream: TcpStream) -> HttpConn {
        HttpConn { stream, buf: Vec::new(), msg_started: None }
    }

    pub fn set_read_timeout(&self, d: Duration) -> Result<()> {
        self.stream.set_read_timeout(Some(d)).context("set_read_timeout")
    }

    /// Is an incomplete message currently buffered?  (Distinguishes a
    /// truly idle keep-alive connection from one mid-upload.)
    pub fn has_partial(&self) -> bool {
        !self.buf.is_empty()
    }

    pub fn write_all(&mut self, bytes: &[u8]) -> Result<()> {
        self.stream.write_all(bytes).context("socket write")?;
        self.stream.flush().context("socket flush")
    }

    /// Read one complete message (head + `Content-Length` body).
    ///
    /// Returns [`Outcome::Idle`] after every read-timeout tick — even
    /// mid-message — so a caller blocked on a slow peer regains control
    /// each tick (shutdown responsiveness).  Partial data stays in the
    /// buffer and the next call resumes; the head is cheap to re-scan.
    pub fn read_message(&mut self) -> Result<Outcome> {
        // Accumulate until the blank line ends the header section.
        let head_end = loop {
            if let Some(pos) = find_blank_line(&self.buf) {
                break pos;
            }
            if self.buf.len() > MAX_HEAD_BYTES {
                bail!("header section exceeds {MAX_HEAD_BYTES} bytes");
            }
            match self.fill()? {
                Fill::Data => {}
                Fill::Eof if self.buf.is_empty() => return Ok(Outcome::Closed),
                Fill::Eof => bail!("connection closed mid-message"),
                Fill::Idle => return Ok(Outcome::Idle),
            }
        };
        let head = std::str::from_utf8(&self.buf[..head_end]).context("non-UTF-8 header")?;
        let (start_line, headers) = parse_head(head)?;
        let body_len = match headers.get("content-length") {
            Some(v) => v.trim().parse::<usize>().with_context(|| format!("content-length {v:?}"))?,
            None => 0,
        };
        if body_len > MAX_BODY_BYTES {
            bail!("body of {body_len} bytes exceeds {MAX_BODY_BYTES}");
        }
        let body_start = head_end + 4;
        while self.buf.len() < body_start + body_len {
            match self.fill()? {
                Fill::Data => {}
                Fill::Eof => bail!("connection closed mid-body"),
                Fill::Idle => return Ok(Outcome::Idle), // resume from buf next call
            }
        }
        let body = self.buf[body_start..body_start + body_len].to_vec();
        // Keep any pipelined bytes for the next message; they already
        // count against the next message's slow-loris deadline.
        self.buf.drain(..body_start + body_len);
        self.msg_started = if self.buf.is_empty() { None } else { Some(Instant::now()) };
        Ok(Outcome::Message(Message { start_line, headers, body }))
    }

    /// One socket read into the buffer.
    fn fill(&mut self) -> Result<Fill> {
        let mut tmp = [0u8; 4096];
        match self.stream.read(&mut tmp) {
            Ok(0) => Ok(Fill::Eof),
            Ok(n) => {
                if self.buf.is_empty() {
                    self.msg_started = Some(Instant::now());
                }
                self.buf.extend_from_slice(&tmp[..n]);
                // Checked on the data path too: a byte-drip client
                // cannot dodge the deadline by always making progress.
                self.check_deadline()?;
                Ok(Fill::Data)
            }
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                self.check_deadline()?;
                Ok(Fill::Idle)
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => Ok(Fill::Idle),
            Err(e) => Err(e).context("socket read"),
        }
    }

    /// Absolute per-message deadline, whatever the arrival pattern.
    fn check_deadline(&self) -> Result<()> {
        if let Some(t0) = self.msg_started {
            if t0.elapsed() > MID_MESSAGE_DEADLINE {
                bail!("message incomplete after {MID_MESSAGE_DEADLINE:?}");
            }
        }
        Ok(())
    }
}

enum Fill {
    Data,
    Eof,
    Idle,
}

fn find_blank_line(buf: &[u8]) -> Option<usize> {
    if buf.len() < 4 {
        return None;
    }
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

fn parse_head(head: &str) -> Result<(String, BTreeMap<String, String>)> {
    let mut lines = head.split("\r\n");
    let start_line = lines.next().ok_or_else(|| anyhow!("empty message head"))?.to_string();
    if start_line.is_empty() {
        bail!("empty start line");
    }
    let mut headers = BTreeMap::new();
    for line in lines {
        if line.is_empty() {
            continue;
        }
        let (k, v) =
            line.split_once(':').ok_or_else(|| anyhow!("malformed header line {line:?}"))?;
        headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
    }
    Ok((start_line, headers))
}

// ---------------------------------------------------------------------------
// Request / response views
// ---------------------------------------------------------------------------

/// A parsed request line + headers + body.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
}

impl Request {
    pub fn from_message(m: Message) -> Result<Request> {
        let mut parts = m.start_line.split_whitespace();
        let method = parts.next().ok_or_else(|| anyhow!("missing method"))?.to_string();
        let path = parts.next().ok_or_else(|| anyhow!("missing request path"))?.to_string();
        let version = parts.next().ok_or_else(|| anyhow!("missing HTTP version"))?;
        if !version.starts_with("HTTP/1.") {
            bail!("unsupported version {version:?}");
        }
        Ok(Request { method, path, headers: m.headers, body: m.body })
    }

    /// Did the client ask to drop keep-alive?
    pub fn wants_close(&self) -> bool {
        self.headers
            .get("connection")
            .map(|v| v.eq_ignore_ascii_case("close"))
            .unwrap_or(false)
    }

    pub fn body_str(&self) -> Result<&str> {
        std::str::from_utf8(&self.body).context("non-UTF-8 body")
    }
}

/// A response under construction; always `Content-Length`-framed.
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
}

impl Response {
    pub fn json(status: u16, v: &Value) -> Response {
        Response { status, content_type: "application/json", body: v.to_string().into_bytes() }
    }

    /// A JSON error envelope: `{"error": msg}`.
    pub fn error(status: u16, msg: &str) -> Response {
        Response::json(status, &Value::obj(vec![("error", Value::from(msg))]))
    }

    pub fn write_to(&self, conn: &mut HttpConn, close: bool) -> Result<()> {
        let head = format!(
            "HTTP/1.1 {} {}\r\ncontent-type: {}\r\ncontent-length: {}\r\nconnection: {}\r\n\r\n",
            self.status,
            reason(self.status),
            self.content_type,
            self.body.len(),
            if close { "close" } else { "keep-alive" },
        );
        conn.write_all(head.as_bytes())?;
        conn.write_all(&self.body)
    }
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Status",
    }
}

// ---------------------------------------------------------------------------
// Client (the device side: loadgen, tests, examples)
// ---------------------------------------------------------------------------

/// A minimal keep-alive HTTP client over one connection.
pub struct Client {
    conn: HttpConn,
}

impl Client {
    pub fn connect(addr: SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        stream.set_nodelay(true).context("set_nodelay")?;
        stream.set_write_timeout(Some(MID_MESSAGE_DEADLINE)).context("set_write_timeout")?;
        let conn = HttpConn::new(stream);
        // Per-read tick; request() keeps waiting while a response is
        // outstanding, so the effective budget is MID_MESSAGE_DEADLINE.
        conn.set_read_timeout(Duration::from_millis(100))?;
        Ok(Client { conn })
    }

    pub fn get(&mut self, path: &str) -> Result<(u16, String)> {
        self.request("GET", path, None)
    }

    pub fn post(&mut self, path: &str, body: &str) -> Result<(u16, String)> {
        self.request("POST", path, Some(body))
    }

    /// Send one request and block for the response (status, body).
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&str>,
    ) -> Result<(u16, String)> {
        let body = body.unwrap_or("");
        let head = format!(
            "{method} {path} HTTP/1.1\r\nhost: pbsp\r\ncontent-length: {}\r\n\r\n",
            body.len()
        );
        self.conn.write_all(head.as_bytes())?;
        self.conn.write_all(body.as_bytes())?;
        let started = Instant::now();
        loop {
            match self.conn.read_message()? {
                Outcome::Message(m) => {
                    let status = m
                        .start_line
                        .split_whitespace()
                        .nth(1)
                        .and_then(|s| s.parse::<u16>().ok())
                        .ok_or_else(|| anyhow!("bad status line {:?}", m.start_line))?;
                    let text = String::from_utf8(m.body).context("non-UTF-8 response body")?;
                    return Ok((status, text));
                }
                Outcome::Closed => bail!("server closed the connection"),
                Outcome::Idle => {
                    if started.elapsed() > MID_MESSAGE_DEADLINE {
                        bail!("no response within {MID_MESSAGE_DEADLINE:?}");
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    #[test]
    fn parses_head_and_framing() {
        let (start, headers) =
            parse_head("POST /v1/x HTTP/1.1\r\nContent-Length: 5\r\nX-A:  b ").unwrap();
        assert_eq!(start, "POST /v1/x HTTP/1.1");
        assert_eq!(headers["content-length"], "5");
        assert_eq!(headers["x-a"], "b");
        assert!(parse_head("").is_err());
        assert!(parse_head("GET / HTTP/1.1\r\nnocolon").is_err());
    }

    #[test]
    fn request_view_rejects_garbage() {
        let msg = |line: &str| Message {
            start_line: line.to_string(),
            headers: BTreeMap::new(),
            body: Vec::new(),
        };
        assert!(Request::from_message(msg("GET /p HTTP/1.1")).is_ok());
        assert!(Request::from_message(msg("GET /p")).is_err());
        assert!(Request::from_message(msg("GET /p SPDY/3")).is_err());
    }

    /// Framing over a real socket pair: two pipelined requests in one
    /// write, bodies split across packets, keep-alive buffering.
    #[test]
    fn socket_framing_roundtrip() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let writer = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            // First request + start of the second in one segment.
            s.write_all(b"POST /a HTTP/1.1\r\ncontent-length: 3\r\n\r\nabcPOST /b HTTP").unwrap();
            s.flush().unwrap();
            std::thread::sleep(Duration::from_millis(30));
            s.write_all(b"/1.1\r\ncontent-length: 2\r\n\r\nxy").unwrap();
            s.flush().unwrap();
        });
        let (stream, _) = listener.accept().unwrap();
        stream.set_read_timeout(Some(Duration::from_millis(50))).unwrap();
        let mut conn = HttpConn::new(stream);
        // Idle ticks (partial message buffered) are resumable.
        let mut next = |conn: &mut HttpConn| loop {
            match conn.read_message().unwrap() {
                Outcome::Message(m) => break m,
                Outcome::Idle => continue,
                Outcome::Closed => panic!("unexpected close"),
            }
        };
        let m1 = next(&mut conn);
        assert_eq!(m1.start_line, "POST /a HTTP/1.1");
        assert_eq!(m1.body, b"abc");
        let m2 = next(&mut conn);
        assert_eq!(m2.start_line, "POST /b HTTP/1.1");
        assert_eq!(m2.body, b"xy");
        writer.join().unwrap();
        // Peer done: next read sees a clean close.
        match conn.read_message().unwrap() {
            Outcome::Closed => {}
            other => panic!("want closed, got {other:?}"),
        }
    }

    #[test]
    fn idle_then_close_detected() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (stream, _) = listener.accept().unwrap();
        stream.set_read_timeout(Some(Duration::from_millis(20))).unwrap();
        let mut conn = HttpConn::new(stream);
        // Nothing sent yet: idle tick, not an error.
        assert!(matches!(conn.read_message().unwrap(), Outcome::Idle));
        drop(client);
        assert!(matches!(conn.read_message().unwrap(), Outcome::Closed));
    }
}
