//! HTTP/1.1 wire format (offline substitute for hyper/axum): message
//! framing over `TcpStream`, request/response views, and the small
//! client the load generator and tests drive real sockets with.
//!
//! Scope is deliberately the serving subset the frontend needs:
//! `Content-Length` framing only (no chunked transfer encoding), CRLF
//! header sections, persistent connections by default (HTTP/1.1
//! keep-alive) with `Connection: close` honoured.  Both sides of the
//! conversation — [`HttpConn`] under the server's reactor and
//! [`Client`] under the device fleet — share the same framing code.
//!
//! [`HttpConn`] is a *resumable* state machine: a read that stops short
//! of a full message (timeout on a blocking socket, `WouldBlock` on a
//! non-blocking one) returns [`Outcome::Idle`] and the next call picks
//! up exactly where it left off — the blank-line scan offset and the
//! parsed head both persist across resumes, so a slow N-byte upload
//! costs O(N) total scanning and one head parse, not O(N²)/O(ticks)
//! (this is what lets the reactor drive thousands of dribbling
//! connections).  Writes are symmetric: responses are queued into an
//! outbound buffer and drained with non-blocking [`HttpConn::flush_progress`]
//! calls, so a peer that stops reading can never block the writer.

use std::collections::BTreeMap;
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Value;

/// Cap on the header section of one message.
pub const MAX_HEAD_BYTES: usize = 64 * 1024;
/// Cap on one message body (a full-batch score request is ~100 KiB).
pub const MAX_BODY_BYTES: usize = 16 * 1024 * 1024;
/// Default deadline for finishing a message whose first bytes have
/// arrived (slow-loris guard: a half-sent request cannot pin resources
/// forever).  Configurable per connection via
/// [`HttpConn::set_msg_deadline`].
pub const MID_MESSAGE_DEADLINE: Duration = Duration::from_secs(30);

/// One framed HTTP message: start line, headers (keys lower-cased),
/// body.  Requests and responses differ only in the start line.
#[derive(Debug)]
pub struct Message {
    pub start_line: String,
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
    /// First byte → complete frame (None when the whole message was
    /// already buffered, e.g. a pipelined request) — the "read" stage
    /// of a request trace span.
    pub read_age: Option<Duration>,
}

/// Result of one [`HttpConn::read_message`] call.
#[derive(Debug)]
pub enum Outcome {
    /// A complete message arrived.
    Message(Message),
    /// The peer closed the connection cleanly between messages.
    Closed,
    /// The socket has no more data right now (read timeout on a
    /// blocking socket, `WouldBlock` on a non-blocking one).  Any
    /// partial message stays buffered — scan offset and parsed head
    /// included — so the caller can check its own conditions (shutdown
    /// flag, keep-alive budget) and simply call `read_message` again
    /// to resume.
    Idle,
}

/// A head parsed while its body is still arriving — persists across
/// [`Outcome::Idle`] resumes so the head is parsed exactly once.
#[derive(Debug)]
struct ParsedHead {
    start_line: String,
    headers: BTreeMap<String, String>,
    /// Byte offset of the body in the connection buffer.
    body_start: usize,
    body_len: usize,
}

/// A TCP connection with resumable message framing, pipelining-safe
/// buffering (bytes past the current message are kept for the next
/// read) and a buffered non-blocking write side.
pub struct HttpConn {
    stream: TcpStream,
    buf: Vec<u8>,
    /// Bytes of `buf` already scanned without finding the head's blank
    /// line — the next scan resumes here (minus a 3-byte overlap for a
    /// terminator split across reads).
    scanned: usize,
    /// The current message's head once parsed, while the body arrives.
    head: Option<ParsedHead>,
    /// When the currently-buffered (incomplete) message started
    /// arriving — the slow-loris deadline baseline, surviving across
    /// `read_message` calls that return [`Outcome::Idle`].
    msg_started: Option<Instant>,
    deadline: Duration,
    /// Outbound bytes not yet accepted by the socket.
    out: Vec<u8>,
    out_pos: usize,
    // Lifetime instrumentation pinning the O(N) resume contract
    // (`wire_stats`): total bytes examined by head scans, and how many
    // times a head was parsed.
    scan_bytes: u64,
    head_parses: u64,
}

impl HttpConn {
    pub fn new(stream: TcpStream) -> HttpConn {
        HttpConn {
            stream,
            buf: Vec::new(),
            scanned: 0,
            head: None,
            msg_started: None,
            deadline: MID_MESSAGE_DEADLINE,
            out: Vec::new(),
            out_pos: 0,
            scan_bytes: 0,
            head_parses: 0,
        }
    }

    pub fn set_read_timeout(&self, d: Duration) -> Result<()> {
        self.stream.set_read_timeout(Some(d)).context("set_read_timeout")
    }

    /// Switch the socket between blocking (handler/client style) and
    /// non-blocking (reactor style) modes.
    pub fn set_nonblocking(&self, on: bool) -> Result<()> {
        self.stream.set_nonblocking(on).context("set_nonblocking")
    }

    /// Override the mid-message deadline (tests use short ones).
    pub fn set_msg_deadline(&mut self, d: Duration) {
        self.deadline = d;
    }

    /// The underlying socket (the reactor registers its fd).
    pub fn stream(&self) -> &TcpStream {
        &self.stream
    }

    /// Is an incomplete message currently buffered?  (Distinguishes a
    /// truly idle keep-alive connection from one mid-upload.)
    pub fn has_partial(&self) -> bool {
        !self.buf.is_empty()
    }

    /// How long the currently-buffered partial message has been
    /// arriving (None when between messages) — the reactor's deadline
    /// input for peers that go silent mid-message.
    pub fn msg_age(&self) -> Option<Duration> {
        self.msg_started.map(|t| t.elapsed())
    }

    /// (total bytes examined by head scans, number of head parses) over
    /// the connection's lifetime — the regression hook for the O(N)
    /// resumable-framing contract.
    pub fn wire_stats(&self) -> (u64, u64) {
        (self.scan_bytes, self.head_parses)
    }

    pub fn write_all(&mut self, bytes: &[u8]) -> Result<()> {
        self.stream.write_all(bytes).context("socket write")?;
        self.stream.flush().context("socket flush")
    }

    /// Read one complete message (head + `Content-Length` body).
    ///
    /// Returns [`Outcome::Idle`] whenever the socket has nothing more
    /// right now — even mid-message — so the caller regains control
    /// (shutdown responsiveness on blocking sockets, readiness loops on
    /// non-blocking ones).  Partial state persists and the next call
    /// resumes in O(new bytes).
    pub fn read_message(&mut self) -> Result<Outcome> {
        loop {
            if let Some(m) = self.try_take_message()? {
                return Ok(Outcome::Message(m));
            }
            match self.fill()? {
                Fill::Data => {}
                Fill::Eof if self.buf.is_empty() => return Ok(Outcome::Closed),
                Fill::Eof => bail!("connection closed mid-message"),
                Fill::Idle => return Ok(Outcome::Idle),
            }
        }
    }

    /// Parse one complete message out of the already-buffered bytes
    /// *without touching the socket* — the pipelining path: after a
    /// response is written, the next request may already be buffered.
    pub fn take_buffered_message(&mut self) -> Result<Option<Message>> {
        self.try_take_message()
    }

    /// Advance the framing state machine over the buffered bytes.
    fn try_take_message(&mut self) -> Result<Option<Message>> {
        if self.head.is_none() {
            // Resume the blank-line scan where the last one stopped
            // (3-byte overlap catches a terminator split across reads).
            let from = self.scanned.saturating_sub(3);
            self.scan_bytes += (self.buf.len() - from) as u64;
            match find_blank_line(&self.buf[from..]) {
                Some(rel) => {
                    let head_end = from + rel;
                    let head = std::str::from_utf8(&self.buf[..head_end])
                        .context("non-UTF-8 header")?;
                    let (start_line, headers) = parse_head(head)?;
                    self.head_parses += 1;
                    let body_len = match headers.get("content-length") {
                        Some(v) => v
                            .trim()
                            .parse::<usize>()
                            .with_context(|| format!("content-length {v:?}"))?,
                        None => 0,
                    };
                    if body_len > MAX_BODY_BYTES {
                        bail!("body of {body_len} bytes exceeds {MAX_BODY_BYTES}");
                    }
                    let body_start = head_end + 4;
                    self.head = Some(ParsedHead { start_line, headers, body_start, body_len });
                }
                None => {
                    self.scanned = self.buf.len();
                    if self.buf.len() > MAX_HEAD_BYTES {
                        bail!("header section exceeds {MAX_HEAD_BYTES} bytes");
                    }
                    return Ok(None);
                }
            }
        }
        let (body_start, body_len) = {
            let h = self.head.as_ref().expect("head just ensured");
            (h.body_start, h.body_len)
        };
        if self.buf.len() < body_start + body_len {
            return Ok(None); // body still arriving; resume later
        }
        let h = self.head.take().expect("head present");
        let body = self.buf[body_start..body_start + body_len].to_vec();
        let read_age = self.msg_started.map(|t| t.elapsed());
        // Keep any pipelined bytes for the next message; they already
        // count against the next message's slow-loris deadline.
        self.buf.drain(..body_start + body_len);
        self.scanned = 0;
        self.msg_started = if self.buf.is_empty() { None } else { Some(Instant::now()) };
        Ok(Some(Message { start_line: h.start_line, headers: h.headers, body, read_age }))
    }

    /// One socket read into the buffer.
    fn fill(&mut self) -> Result<Fill> {
        let mut tmp = [0u8; 4096];
        match self.stream.read(&mut tmp) {
            Ok(0) => Ok(Fill::Eof),
            Ok(n) => {
                if self.buf.is_empty() {
                    self.msg_started = Some(Instant::now());
                }
                self.buf.extend_from_slice(&tmp[..n]);
                // Checked on the data path too: a byte-drip client
                // cannot dodge the deadline by always making progress.
                self.check_deadline()?;
                Ok(Fill::Data)
            }
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                self.check_deadline()?;
                Ok(Fill::Idle)
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => Ok(Fill::Idle),
            Err(e) => Err(e).context("socket read"),
        }
    }

    /// Absolute per-message deadline, whatever the arrival pattern.
    fn check_deadline(&self) -> Result<()> {
        if let Some(t0) = self.msg_started {
            if t0.elapsed() > self.deadline {
                bail!("message incomplete after {:?}", self.deadline);
            }
        }
        Ok(())
    }

    // -- buffered write side (reactor) ---------------------------------

    /// Queue a response for non-blocking draining.
    pub fn queue_response(&mut self, resp: &Response, close: bool) {
        resp.append_to(&mut self.out, close);
    }

    pub fn has_pending_write(&self) -> bool {
        self.out_pos < self.out.len()
    }

    /// Push queued bytes into the socket without blocking.  Returns
    /// (bytes written this call, fully drained?).  `WouldBlock` is
    /// progress-zero, not an error — the caller re-arms for
    /// write-readiness and retries.
    pub fn flush_progress(&mut self) -> Result<(usize, bool)> {
        let mut wrote = 0usize;
        while self.out_pos < self.out.len() {
            match self.stream.write(&self.out[self.out_pos..]) {
                Ok(0) => bail!("socket write returned zero"),
                Ok(n) => {
                    self.out_pos += n;
                    wrote += n;
                }
                Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => return Err(e).context("socket write"),
            }
        }
        if self.out_pos == self.out.len() {
            self.out.clear();
            self.out_pos = 0;
            return Ok((wrote, true));
        }
        if self.out_pos > 64 * 1024 {
            // Bound memory on long drains against a slow reader.
            self.out.drain(..self.out_pos);
            self.out_pos = 0;
        }
        Ok((wrote, false))
    }
}

enum Fill {
    Data,
    Eof,
    Idle,
}

fn find_blank_line(buf: &[u8]) -> Option<usize> {
    if buf.len() < 4 {
        return None;
    }
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

fn parse_head(head: &str) -> Result<(String, BTreeMap<String, String>)> {
    let mut lines = head.split("\r\n");
    let start_line = lines.next().ok_or_else(|| anyhow!("empty message head"))?.to_string();
    if start_line.is_empty() {
        bail!("empty start line");
    }
    let mut headers = BTreeMap::new();
    for line in lines {
        if line.is_empty() {
            continue;
        }
        let (k, v) =
            line.split_once(':').ok_or_else(|| anyhow!("malformed header line {line:?}"))?;
        headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
    }
    Ok((start_line, headers))
}

// ---------------------------------------------------------------------------
// Request / response views
// ---------------------------------------------------------------------------

/// A parsed request line + headers + body.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
}

impl Request {
    pub fn from_message(m: Message) -> Result<Request> {
        let mut parts = m.start_line.split_whitespace();
        let method = parts.next().ok_or_else(|| anyhow!("missing method"))?.to_string();
        let path = parts.next().ok_or_else(|| anyhow!("missing request path"))?.to_string();
        let version = parts.next().ok_or_else(|| anyhow!("missing HTTP version"))?;
        if !version.starts_with("HTTP/1.") {
            bail!("unsupported version {version:?}");
        }
        Ok(Request { method, path, headers: m.headers, body: m.body })
    }

    /// Did the client ask to drop keep-alive?
    pub fn wants_close(&self) -> bool {
        self.headers
            .get("connection")
            .map(|v| v.eq_ignore_ascii_case("close"))
            .unwrap_or(false)
    }

    pub fn body_str(&self) -> Result<&str> {
        std::str::from_utf8(&self.body).context("non-UTF-8 body")
    }
}

/// A response under construction; always `Content-Length`-framed.
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
    /// Emitted as a `Retry-After: N` header — set on backpressure 503s
    /// so well-behaved devices pace their reconnects.
    pub retry_after: Option<u64>,
}

impl Response {
    pub fn json(status: u16, v: &Value) -> Response {
        Response {
            status,
            content_type: "application/json",
            body: v.to_string().into_bytes(),
            retry_after: None,
        }
    }

    /// A JSON error envelope: `{"error": msg}`.
    pub fn error(status: u16, msg: &str) -> Response {
        Response::json(status, &Value::obj(vec![("error", Value::from(msg))]))
    }

    /// The backpressure envelope: `503` + `Retry-After` (visible,
    /// pace-able overload instead of silent refusal).
    pub fn unavailable(msg: &str, retry_after_s: u64) -> Response {
        let mut r = Response::error(503, msg);
        r.retry_after = Some(retry_after_s);
        r
    }

    /// Serialize head + body into `out` (the reactor's queued-write
    /// form; [`Response::write_to`] is the blocking form).
    pub fn append_to(&self, out: &mut Vec<u8>, close: bool) {
        let retry = match self.retry_after {
            Some(s) => format!("retry-after: {s}\r\n"),
            None => String::new(),
        };
        let head = format!(
            "HTTP/1.1 {} {}\r\ncontent-type: {}\r\ncontent-length: {}\r\n{}connection: {}\r\n\r\n",
            self.status,
            reason(self.status),
            self.content_type,
            self.body.len(),
            retry,
            if close { "close" } else { "keep-alive" },
        );
        out.extend_from_slice(head.as_bytes());
        out.extend_from_slice(&self.body);
    }

    pub fn write_to(&self, conn: &mut HttpConn, close: bool) -> Result<()> {
        let mut bytes = Vec::with_capacity(self.body.len() + 128);
        self.append_to(&mut bytes, close);
        conn.write_all(&bytes)
    }
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Status",
    }
}

// ---------------------------------------------------------------------------
// Client (the device side: loadgen, tests, examples)
// ---------------------------------------------------------------------------

/// Socket budgets for [`Client`].  Every phase of a request — connect,
/// write, response wait — is bounded, so a blackholed or stalled server
/// costs the caller a bounded error, never a hang (ISSUE 10: the
/// pre-existing `TcpStream::connect` call and the hard-coded response
/// wait were the last unbounded client operations).
#[derive(Debug, Clone, Copy)]
pub struct ClientConfig {
    pub connect_timeout: Duration,
    /// Budget from request written to response framed; also the
    /// mid-message deadline for a response that starts arriving and
    /// then stalls.
    pub response_timeout: Duration,
}

impl Default for ClientConfig {
    fn default() -> ClientConfig {
        ClientConfig {
            connect_timeout: Duration::from_secs(3),
            response_timeout: Duration::from_secs(15),
        }
    }
}

/// A minimal keep-alive HTTP client over one connection.
pub struct Client {
    conn: HttpConn,
    response_timeout: Duration,
}

impl Client {
    pub fn connect(addr: SocketAddr) -> Result<Client> {
        Self::connect_with(addr, ClientConfig::default())
    }

    pub fn connect_with(addr: SocketAddr, cfg: ClientConfig) -> Result<Client> {
        let stream = TcpStream::connect_timeout(&addr, cfg.connect_timeout)
            .with_context(|| format!("connect {addr}"))?;
        stream.set_nodelay(true).context("set_nodelay")?;
        stream.set_write_timeout(Some(cfg.response_timeout)).context("set_write_timeout")?;
        let mut conn = HttpConn::new(stream);
        conn.set_msg_deadline(cfg.response_timeout);
        // Per-read tick; request() keeps waiting while a response is
        // outstanding, so the effective budget is `response_timeout`.
        conn.set_read_timeout(Duration::from_millis(100))?;
        Ok(Client { conn, response_timeout: cfg.response_timeout })
    }

    pub fn get(&mut self, path: &str) -> Result<(u16, String)> {
        self.request("GET", path, None)
    }

    pub fn post(&mut self, path: &str, body: &str) -> Result<(u16, String)> {
        self.request("POST", path, Some(body))
    }

    /// Send one request and block for the response (status, body).
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&str>,
    ) -> Result<(u16, String)> {
        let (status, _, text) = self.request_meta(method, path, body, &[])?;
        Ok((status, text))
    }

    /// [`Client::request`] exposing the response headers (retry logic
    /// needs `Retry-After`) and taking extra request headers (deadline
    /// propagation sends `X-Deadline-Ms`).
    pub fn request_meta(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&str>,
        extra_headers: &[(&str, String)],
    ) -> Result<(u16, BTreeMap<String, String>, String)> {
        let body = body.unwrap_or("");
        let mut head = format!("{method} {path} HTTP/1.1\r\nhost: pbsp\r\n");
        for (k, v) in extra_headers {
            head.push_str(k);
            head.push_str(": ");
            head.push_str(v);
            head.push_str("\r\n");
        }
        head.push_str(&format!("content-length: {}\r\n\r\n", body.len()));
        self.conn.write_all(head.as_bytes())?;
        self.conn.write_all(body.as_bytes())?;
        let started = Instant::now();
        loop {
            match self.conn.read_message()? {
                Outcome::Message(m) => {
                    let status = m
                        .start_line
                        .split_whitespace()
                        .nth(1)
                        .and_then(|s| s.parse::<u16>().ok())
                        .ok_or_else(|| anyhow!("bad status line {:?}", m.start_line))?;
                    let text = String::from_utf8(m.body).context("non-UTF-8 response body")?;
                    return Ok((status, m.headers, text));
                }
                Outcome::Closed => bail!("server closed the connection"),
                Outcome::Idle => {
                    if started.elapsed() > self.response_timeout {
                        bail!("no response within {:?}", self.response_timeout);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    #[test]
    fn parses_head_and_framing() {
        let (start, headers) =
            parse_head("POST /v1/x HTTP/1.1\r\nContent-Length: 5\r\nX-A:  b ").unwrap();
        assert_eq!(start, "POST /v1/x HTTP/1.1");
        assert_eq!(headers["content-length"], "5");
        assert_eq!(headers["x-a"], "b");
        assert!(parse_head("").is_err());
        assert!(parse_head("GET / HTTP/1.1\r\nnocolon").is_err());
    }

    #[test]
    fn request_view_rejects_garbage() {
        let msg = |line: &str| Message {
            start_line: line.to_string(),
            headers: BTreeMap::new(),
            body: Vec::new(),
            read_age: None,
        };
        assert!(Request::from_message(msg("GET /p HTTP/1.1")).is_ok());
        assert!(Request::from_message(msg("GET /p")).is_err());
        assert!(Request::from_message(msg("GET /p SPDY/3")).is_err());
    }

    /// Framing over a real socket pair: two pipelined requests in one
    /// write, bodies split across packets, keep-alive buffering.
    #[test]
    fn socket_framing_roundtrip() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let writer = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            // First request + start of the second in one segment.
            s.write_all(b"POST /a HTTP/1.1\r\ncontent-length: 3\r\n\r\nabcPOST /b HTTP").unwrap();
            s.flush().unwrap();
            std::thread::sleep(Duration::from_millis(30));
            s.write_all(b"/1.1\r\ncontent-length: 2\r\n\r\nxy").unwrap();
            s.flush().unwrap();
        });
        let (stream, _) = listener.accept().unwrap();
        stream.set_read_timeout(Some(Duration::from_millis(50))).unwrap();
        let mut conn = HttpConn::new(stream);
        // Idle ticks (partial message buffered) are resumable.
        let mut next = |conn: &mut HttpConn| loop {
            match conn.read_message().unwrap() {
                Outcome::Message(m) => break m,
                Outcome::Idle => continue,
                Outcome::Closed => panic!("unexpected close"),
            }
        };
        let m1 = next(&mut conn);
        assert_eq!(m1.start_line, "POST /a HTTP/1.1");
        assert_eq!(m1.body, b"abc");
        let m2 = next(&mut conn);
        assert_eq!(m2.start_line, "POST /b HTTP/1.1");
        assert_eq!(m2.body, b"xy");
        writer.join().unwrap();
        // Peer done: next read sees a clean close.
        match conn.read_message().unwrap() {
            Outcome::Closed => {}
            other => panic!("want closed, got {other:?}"),
        }
    }

    #[test]
    fn idle_then_close_detected() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (stream, _) = listener.accept().unwrap();
        stream.set_read_timeout(Some(Duration::from_millis(20))).unwrap();
        let mut conn = HttpConn::new(stream);
        // Nothing sent yet: idle tick, not an error.
        assert!(matches!(conn.read_message().unwrap(), Outcome::Idle));
        drop(client);
        assert!(matches!(conn.read_message().unwrap(), Outcome::Closed));
    }

    /// A connection pair where the test drips bytes into the server
    /// side's buffer directly, simulating arbitrarily slow arrival with
    /// a deterministic resume count.
    fn quiet_pair() -> (TcpStream, HttpConn) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (stream, _) = listener.accept().unwrap();
        stream.set_read_timeout(Some(Duration::from_millis(1))).unwrap();
        (client, HttpConn::new(stream))
    }

    /// Regression (ISSUE 7): resuming a slow upload must not rescan the
    /// buffer or re-parse the head each tick — O(N) total scan work and
    /// exactly one head parse, no matter how many resumes.
    #[test]
    fn slow_upload_resume_is_linear() {
        let body_len = 40_000usize;
        let head = format!("POST /v1/score/m/p8 HTTP/1.1\r\ncontent-length: {body_len}\r\n\r\n");
        let (_client, mut conn) = quiet_pair();
        // Drip the head a few bytes per resume, then the body in many
        // chunks; every gap forces read_message back through Idle.
        let feed = |conn: &mut HttpConn, bytes: &[u8]| {
            conn.buf.extend_from_slice(bytes);
            match conn.read_message().unwrap() {
                Outcome::Idle => None,
                Outcome::Message(m) => Some(m),
                Outcome::Closed => panic!("unexpected close"),
            }
        };
        let mut got = None;
        for chunk in head.as_bytes().chunks(7) {
            assert!(feed(&mut conn, chunk).is_none(), "head not complete yet");
        }
        let body = vec![b'x'; body_len];
        for chunk in body.chunks(400) {
            if let Some(m) = feed(&mut conn, chunk) {
                got = Some(m);
            }
        }
        let m = got.expect("message must complete");
        assert_eq!(m.body.len(), body_len);
        let (scan_bytes, head_parses) = conn.wire_stats();
        assert_eq!(head_parses, 1, "head must be parsed exactly once");
        // The scan only ever walks head bytes (the body phase is a
        // length check): allow the resume overlap but nothing quadratic.
        let head_len = head.len() as u64;
        assert!(
            scan_bytes < head_len * 3,
            "scan work must stay linear: scanned {scan_bytes} for a {head_len}-byte head"
        );
    }

    /// Pipelined second request is parseable from the buffer without a
    /// socket read.
    #[test]
    fn buffered_message_taken_without_socket_read() {
        let (mut client, mut conn) = quiet_pair();
        client
            .write_all(
                b"POST /a HTTP/1.1\r\ncontent-length: 1\r\n\r\nzPOST /b HTTP/1.1\r\n\
                  content-length: 0\r\n\r\n",
            )
            .unwrap();
        client.flush().unwrap();
        let m1 = loop {
            match conn.read_message().unwrap() {
                Outcome::Message(m) => break m,
                Outcome::Idle => continue,
                Outcome::Closed => panic!("unexpected close"),
            }
        };
        assert_eq!(m1.start_line, "POST /a HTTP/1.1");
        let m2 = conn.take_buffered_message().unwrap().expect("pipelined request buffered");
        assert_eq!(m2.start_line, "POST /b HTTP/1.1");
        assert!(conn.take_buffered_message().unwrap().is_none());
    }

    /// The buffered write side drains without blocking and reports
    /// completion.
    #[test]
    fn queued_response_drains_nonblocking() {
        let (client, mut conn) = quiet_pair();
        conn.set_nonblocking(true).unwrap();
        let resp = Response::unavailable("busy", 2);
        conn.queue_response(&resp, true);
        assert!(conn.has_pending_write());
        let mut done = false;
        for _ in 0..100 {
            let (_, d) = conn.flush_progress().unwrap();
            if d {
                done = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(done, "a small response must drain");
        assert!(!conn.has_pending_write());
        // The peer sees the full wire form, Retry-After included.
        let mut peer = HttpConn::new(client);
        peer.set_read_timeout(Duration::from_millis(50)).unwrap();
        let m = loop {
            match peer.read_message().unwrap() {
                Outcome::Message(m) => break m,
                Outcome::Idle => continue,
                Outcome::Closed => panic!("unexpected close"),
            }
        };
        assert!(m.start_line.contains("503"));
        assert_eq!(m.headers["retry-after"], "2");
        assert_eq!(m.headers["connection"], "close");
    }

    /// Satellite (ISSUE 10): a server that accepts and then never
    /// responds (blackhole) costs the client a bounded error, not a
    /// hang.  The listener never accepts — the connection sits in the
    /// backlog, the write buffers, and the response wait must trip.
    #[test]
    fn client_response_timeout_bounds_a_blackholed_server() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let cfg = ClientConfig {
            connect_timeout: Duration::from_secs(2),
            response_timeout: Duration::from_millis(200),
        };
        let mut c = Client::connect_with(addr, cfg).unwrap();
        let t0 = Instant::now();
        let err = c.get("/healthz").expect_err("blackholed server must time out");
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "timeout must trip near the configured budget, waited {:?}",
            t0.elapsed()
        );
        assert!(err.to_string().contains("no response"), "unexpected error: {err:#}");
        drop(listener);
    }

    /// Extra request headers go out on the wire; response headers come
    /// back through `request_meta`.
    #[test]
    fn request_meta_carries_headers_both_ways() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            stream.set_read_timeout(Some(Duration::from_millis(50))).unwrap();
            let mut conn = HttpConn::new(stream);
            let m = loop {
                match conn.read_message().unwrap() {
                    Outcome::Message(m) => break m,
                    Outcome::Idle => continue,
                    Outcome::Closed => panic!("unexpected close"),
                }
            };
            assert_eq!(m.headers["x-deadline-ms"], "250");
            let mut r = Response::error(503, "busy");
            r.retry_after = Some(7);
            r.write_to(&mut conn, true).unwrap();
        });
        let mut c = Client::connect(addr).unwrap();
        let (status, headers, _body) = c
            .request_meta("POST", "/x", Some("{}"), &[("x-deadline-ms", "250".to_string())])
            .unwrap();
        assert_eq!(status, 503);
        assert_eq!(headers["retry-after"], "7");
        server.join().unwrap();
    }

    /// The configurable mid-message deadline trips on a stalled drip.
    #[test]
    fn short_deadline_trips_mid_message() {
        let (mut client, mut conn) = quiet_pair();
        conn.set_msg_deadline(Duration::from_millis(40));
        client.write_all(b"POST /x HTTP/1.1\r\ncontent-le").unwrap();
        client.flush().unwrap();
        // First reads buffer the partial head; once the deadline passes
        // the next read errors out.
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            match conn.read_message() {
                Ok(Outcome::Idle) => {
                    assert!(Instant::now() < deadline, "deadline never tripped");
                    std::thread::sleep(Duration::from_millis(10));
                }
                Ok(other) => panic!("unexpected outcome {other:?}"),
                Err(e) => {
                    assert!(e.to_string().contains("incomplete"), "unexpected error {e:#}");
                    break;
                }
            }
        }
    }
}
