//! Device-fleet load generator: N simulated printed devices (the
//! paper's §I smart-packaging / disposable-healthcare scenario, one
//! ultra-cheap sensor each) driving the HTTP frontend closed-loop over
//! real sockets.
//!
//! Deterministic by construction: device `d` draws its model mix and
//! sample indices from its own PCG stream `Pcg32::new(seed, d)`, and
//! think-times from a *separate* stream (`Pcg32::new(seed, fleet + d)`)
//! so the request sequence depends only on
//! (seed, fleet, requests_per_device) — never on think_ms or response
//! timing.  The e2e test replays every recorded request through direct
//! `Service::submit` and asserts bit-identical scores.
//!
//! Latencies are end-to-end (serialize + socket + parse + batcher +
//! runtime) and reported as nearest-rank percentiles
//! (`util::stats::percentile_nearest`) plus a text histogram the CI
//! smoke job uploads as an artifact.

use std::net::SocketAddr;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use super::http::Client;
use crate::ml::dataset::Dataset;
use crate::ml::manifest::Manifest;
use crate::util::json::Value;
use crate::util::rng::Pcg32;
use crate::util::stats::percentile_nearest_sorted;

#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Number of simulated devices, each with one keep-alive connection.
    pub fleet: usize,
    /// Closed-loop requests per device.
    pub requests_per_device: usize,
    /// Master seed; device `d` uses PCG stream `d`.
    pub seed: u64,
    /// Upper bound on the uniform per-request think-time (0 = none).
    pub think_ms: u64,
    /// Precision variant to score at (`p{precision}`).
    pub precision: u32,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig { fleet: 8, requests_per_device: 50, seed: 1, think_ms: 0, precision: 8 }
    }
}

/// One successful scored request, with everything needed to replay it.
#[derive(Debug, Clone)]
pub struct DeviceRecord {
    pub device: usize,
    pub seq: usize,
    /// Model index into the manifest's model list.
    pub model: usize,
    /// Sample index into that model's test set.
    pub sample: usize,
    pub scores: Vec<f64>,
    pub latency_ms: f64,
}

/// Aggregate fleet results.
#[derive(Debug, Clone)]
pub struct Report {
    pub records: Vec<DeviceRecord>,
    pub errors: usize,
    pub wall_s: f64,
    pub rps: f64,
    pub p50_ms: f64,
    pub p90_ms: f64,
    pub p99_ms: f64,
    cfg: LoadgenConfig,
}

impl Report {
    pub fn summary(&self) -> String {
        format!(
            "loadgen: fleet {} x {} requests -> {} ok, errors {}, wall {:.3}s, {:.0} req/s\n\
             latency p50 {:.2} ms  p90 {:.2} ms  p99 {:.2} ms",
            self.cfg.fleet,
            self.cfg.requests_per_device,
            self.records.len(),
            self.errors,
            self.wall_s,
            self.rps,
            self.p50_ms,
            self.p90_ms,
            self.p99_ms
        )
    }

    /// Text latency histogram (16 linear buckets) for logging/upload.
    pub fn histogram(&self) -> String {
        let lat: Vec<f64> = self.records.iter().map(|r| r.latency_ms).collect();
        let mut out = format!(
            "# pbsp loadgen latency histogram (ms)\n\
             # fleet {} x {} requests, seed {}, p{}\n\
             # n {}  errors {}  p50 {:.3}  p90 {:.3}  p99 {:.3}  {:.0} req/s\n",
            self.cfg.fleet,
            self.cfg.requests_per_device,
            self.cfg.seed,
            self.cfg.precision,
            lat.len(),
            self.errors,
            self.p50_ms,
            self.p90_ms,
            self.p99_ms,
            self.rps
        );
        if lat.is_empty() {
            return out;
        }
        let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
        for &v in &lat {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        let buckets = 16usize;
        let width = ((hi - lo) / buckets as f64).max(1e-9);
        let mut counts = vec![0usize; buckets];
        for &v in &lat {
            let b = (((v - lo) / width) as usize).min(buckets - 1);
            counts[b] += 1;
        }
        let peak = counts.iter().copied().max().unwrap_or(1).max(1);
        for (b, &c) in counts.iter().enumerate() {
            let bar = "#".repeat(c * 40 / peak);
            out.push_str(&format!(
                "{:>9.3}-{:<9.3} ms | {:>6} {bar}\n",
                lo + b as f64 * width,
                lo + (b + 1) as f64 * width,
                c
            ));
        }
        out
    }
}

/// Run a fleet against a listening frontend.  Loads the artifact tree
/// client-side (devices own their sensor data), spawns one OS thread
/// per device, merges records in (device, seq) order.
pub fn run(addr: SocketAddr, cfg: &LoadgenConfig) -> Result<Report> {
    if cfg.fleet == 0 || cfg.requests_per_device == 0 {
        bail!("fleet and requests_per_device must be positive");
    }
    let dir = crate::artifacts_dir()?;
    let manifest = Manifest::load(&dir)?;
    let datasets: Vec<Dataset> = manifest
        .models
        .iter()
        .map(|m| Dataset::load(manifest.data_dir(), &m.dataset, "test"))
        .collect::<Result<_>>()?;
    for (m, ds) in manifest.models.iter().zip(&datasets) {
        if ds.is_empty() {
            bail!("model {:?}: empty test set", m.name);
        }
    }
    let names: Arc<Vec<String>> =
        Arc::new(manifest.models.iter().map(|m| m.name.clone()).collect());
    let datasets = Arc::new(datasets);

    let t0 = Instant::now();
    let handles: Vec<_> = (0..cfg.fleet)
        .map(|d| {
            let names = Arc::clone(&names);
            let datasets = Arc::clone(&datasets);
            let cfg = cfg.clone();
            std::thread::Builder::new()
                .name(format!("pbsp-device-{d}"))
                .spawn(move || device_loop(addr, d, &names, &datasets, &cfg))
                .context("spawn device thread")
        })
        .collect::<Result<_>>()?;
    let mut records = Vec::with_capacity(cfg.fleet * cfg.requests_per_device);
    let mut errors = 0usize;
    for h in handles {
        let (recs, errs) = h.join().map_err(|_| anyhow!("device thread panicked"))?;
        records.extend(recs);
        errors += errs;
    }
    let wall_s = t0.elapsed().as_secs_f64();
    records.sort_by_key(|r: &DeviceRecord| (r.device, r.seq));
    let mut lat: Vec<f64> = records.iter().map(|r| r.latency_ms).collect();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Ok(Report {
        rps: records.len() as f64 / wall_s.max(1e-9),
        p50_ms: percentile_nearest_sorted(&lat, 50.0),
        p90_ms: percentile_nearest_sorted(&lat, 90.0),
        p99_ms: percentile_nearest_sorted(&lat, 99.0),
        records,
        errors,
        wall_s,
        cfg: cfg.clone(),
    })
}

/// One device: keep-alive connection, closed-loop request sequence
/// drawn from its own PCG stream.  Returns (records, error count).
fn device_loop(
    addr: SocketAddr,
    device: usize,
    names: &[String],
    datasets: &[Dataset],
    cfg: &LoadgenConfig,
) -> (Vec<DeviceRecord>, usize) {
    let mut rng = Pcg32::new(cfg.seed, device as u64);
    // Think-times come from their own stream (offset past every
    // device's request stream), so the request sequence is identical
    // at any think_ms setting.
    let mut think_rng = Pcg32::new(cfg.seed, (cfg.fleet + device) as u64);
    let mut client = match Client::connect(addr) {
        Ok(c) => Some(c),
        Err(_) => None,
    };
    let mut records = Vec::with_capacity(cfg.requests_per_device);
    let mut errors = 0usize;
    for seq in 0..cfg.requests_per_device {
        let model = rng.below(names.len() as u64) as usize;
        let sample = rng.below(datasets[model].len() as u64) as usize;
        let path = format!("/v1/score/{}/p{}", names[model], cfg.precision);
        let body = score_body(&datasets[model].x[sample]);
        let t = Instant::now();
        match post_with_retry(&mut client, addr, &path, &body) {
            Ok(text) => match parse_scores(&text) {
                Ok(scores) => records.push(DeviceRecord {
                    device,
                    seq,
                    model,
                    sample,
                    scores,
                    latency_ms: t.elapsed().as_secs_f64() * 1e3,
                }),
                Err(_) => errors += 1,
            },
            Err(_) => errors += 1,
        }
        if cfg.think_ms > 0 {
            let think = think_rng.below(cfg.think_ms + 1);
            std::thread::sleep(Duration::from_millis(think));
        }
    }
    (records, errors)
}

/// POST with one reconnect retry for *transport* failures: the server
/// reaps idle keep-alive connections (think-time fleets), so a device
/// whose connection was reaped reconnects and repeats — safe because
/// scoring is read-only.  HTTP-level failures (including the server's
/// 503 over-capacity refusal) are deterministic and surface as errors
/// immediately.
fn post_with_retry(
    client: &mut Option<Client>,
    addr: SocketAddr,
    path: &str,
    body: &str,
) -> Result<String> {
    for _attempt in 0..2 {
        if client.is_none() {
            *client = Some(Client::connect(addr)?);
        }
        let c = client.as_mut().expect("client just connected");
        match c.post(path, body) {
            Ok((200, text)) => return Ok(text),
            Ok((status, text)) => bail!("HTTP {status}: {text}"),
            Err(_) => *client = None, // dead connection: reconnect once
        }
    }
    bail!("request failed after reconnect")
}

fn score_body(x: &[f32]) -> String {
    let row = Value::Arr(x.iter().map(|&v| Value::Num(v as f64)).collect());
    Value::obj(vec![("x", row)]).to_string()
}

fn parse_scores(text: &str) -> Result<Vec<f64>> {
    Value::parse(text)?.get("scores")?.as_f64_vec()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::percentile_nearest;

    #[test]
    fn score_body_roundtrips_f32_exactly() {
        let x = [0.1f32, -3.5, 2.0, 1e-7];
        let body = score_body(&x);
        let v = Value::parse(&body).unwrap();
        let back: Vec<f32> =
            v.get("x").unwrap().as_f64_vec().unwrap().into_iter().map(|f| f as f32).collect();
        assert_eq!(back, x, "JSON number round-trip must be exact for f32 inputs");
    }

    #[test]
    fn device_sequences_are_deterministic_and_distinct() {
        let draw = |seed, device: usize| {
            let mut rng = Pcg32::new(seed, device as u64);
            (0..16).map(|_| (rng.below(6), rng.below(40))).collect::<Vec<_>>()
        };
        assert_eq!(draw(1, 0), draw(1, 0));
        assert_ne!(draw(1, 0), draw(1, 1));
        assert_ne!(draw(1, 0), draw(2, 0));
    }

    #[test]
    fn histogram_renders_counts() {
        let cfg = LoadgenConfig::default();
        let records: Vec<DeviceRecord> = (0..10)
            .map(|i| DeviceRecord {
                device: 0,
                seq: i,
                model: 0,
                sample: i,
                scores: vec![0.0],
                latency_ms: (i + 1) as f64,
            })
            .collect();
        let lat: Vec<f64> = records.iter().map(|r| r.latency_ms).collect();
        let report = Report {
            rps: 10.0,
            p50_ms: percentile_nearest(&lat, 50.0),
            p90_ms: percentile_nearest(&lat, 90.0),
            p99_ms: percentile_nearest(&lat, 99.0),
            records,
            errors: 0,
            wall_s: 1.0,
            cfg,
        };
        let h = report.histogram();
        assert!(h.contains("# n 10  errors 0"));
        assert!(h.lines().count() > 10, "16 buckets expected:\n{h}");
        assert!(report.summary().contains("errors 0"));
    }
}
