//! Device-fleet load generator: N simulated printed devices (the
//! paper's §I smart-packaging / disposable-healthcare scenario, one
//! ultra-cheap sensor each) driving the HTTP frontend over real
//! sockets.
//!
//! Deterministic by construction: device `d` draws its model mix and
//! sample indices from its own PCG stream `Pcg32::new(seed, d)`,
//! think-times from a *separate* stream (`Pcg32::new(seed, fleet + d)`)
//! and retry-backoff jitter from a third (`Pcg32::new(seed,
//! 2*fleet + d)`) so the request sequence depends only on
//! (seed, fleet, requests_per_device) — never on think_ms, arrival
//! mode, worker sharding, retries or response timing.  The e2e test
//! replays every recorded request through direct `Service::submit` and
//! asserts bit-identical scores ([`verify`]) — each record at the
//! precision it was *actually served* at, so a brownout-degraded
//! response verifies against the lower-precision variant it claims.
//!
//! Two arrival modes:
//!
//! * **closed-loop** (default) — each device sends its next request as
//!   soon as the previous response (plus an optional think-time)
//!   arrives; throughput self-adjusts to server speed.
//! * **open-loop** (`open_rps > 0`) — requests are launched on a fixed
//!   fleet-wide schedule regardless of response latency, and each
//!   latency is measured from its *scheduled* start, so server-side
//!   queueing is visible instead of coordinated-omission-hidden.
//!
//! Devices are sharded onto a bounded set of client worker threads
//! (`client_workers`, default `min(fleet, 64)`) — a 10k-device fleet
//! does not need 10k OS threads; each device still owns its keep-alive
//! connection and PCG streams.
//!
//! Latencies are end-to-end (serialize + socket + parse + batcher +
//! runtime) and reported as nearest-rank percentiles
//! (`util::stats::percentile_nearest`) plus a text histogram and a JSON
//! artifact ([`Report::to_json`]) the CI smoke job uploads.

use std::net::SocketAddr;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use super::http::Client;
use crate::coordinator::service::Service;
use crate::ml::dataset::Dataset;
use crate::ml::manifest::Manifest;
use crate::util::json::Value;
use crate::util::rng::Pcg32;
use crate::util::stats::percentile_nearest_sorted;

#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Number of simulated devices, each with one keep-alive connection.
    pub fleet: usize,
    /// Requests per device.
    pub requests_per_device: usize,
    /// Master seed; device `d` uses PCG stream `d`.
    pub seed: u64,
    /// Upper bound on the uniform per-request think-time (0 = none;
    /// closed-loop only).
    pub think_ms: u64,
    /// Precision variant to score at (`p{precision}`).
    pub precision: u32,
    /// Open-loop arrival rate for the whole fleet in requests/s
    /// (0 = closed-loop).
    pub open_rps: f64,
    /// Client worker threads the devices are sharded onto
    /// (0 = `min(fleet, 64)`).
    pub client_workers: usize,
    /// Per-request deadline sent as `X-Deadline-Ms` (0 = none).  A 504
    /// back is counted as a deadline miss, not an error.
    pub deadline_ms: u64,
    /// Total tries per request (first attempt + retries).  Transport
    /// failures and 503 backpressure retry with seeded backoff; any
    /// other non-200 fails immediately.
    pub attempts: usize,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            fleet: 8,
            requests_per_device: 50,
            seed: 1,
            think_ms: 0,
            precision: 8,
            open_rps: 0.0,
            client_workers: 0,
            deadline_ms: 0,
            attempts: 3,
        }
    }
}

impl LoadgenConfig {
    fn workers(&self) -> usize {
        if self.client_workers > 0 {
            self.client_workers.min(self.fleet.max(1))
        } else {
            self.fleet.clamp(1, 64)
        }
    }
}

/// One successful scored request, with everything needed to replay it.
#[derive(Debug, Clone)]
pub struct DeviceRecord {
    pub device: usize,
    pub seq: usize,
    /// Model index into the manifest's model list.
    pub model: usize,
    /// Sample index into that model's test set.
    pub sample: usize,
    pub scores: Vec<f64>,
    pub latency_ms: f64,
    /// Precision the server says it served (may be lower than requested
    /// under brownout) — [`verify`] replays against this, so a lying
    /// label fails the bit-compare.
    pub precision: u32,
    /// Whether the server flagged this response as brownout-degraded.
    pub degraded: bool,
}

/// Aggregate fleet results.
#[derive(Debug, Clone)]
pub struct Report {
    pub records: Vec<DeviceRecord>,
    pub errors: usize,
    /// The first error any device saw (connect refusals included) —
    /// an all-fail run names its cause instead of reporting bare
    /// counts.
    pub first_error: Option<String>,
    /// Requests the server 504'd past their deadline — an overload
    /// outcome, not an error.
    pub deadline_misses: usize,
    /// Successful responses served at a lower precision under brownout.
    pub degraded: usize,
    /// Extra attempts spent on transport failures and 503 backpressure.
    pub retries: usize,
    pub wall_s: f64,
    pub rps: f64,
    pub p50_ms: f64,
    pub p90_ms: f64,
    pub p99_ms: f64,
    /// The frontend's `/metrics` snapshot, scraped while the server is
    /// still up (after every worker joined).  `None` when the scrape
    /// failed — the fleet result stands on its own either way.
    pub server_metrics: Option<Value>,
    cfg: LoadgenConfig,
}

impl Report {
    fn new(
        records: Vec<DeviceRecord>,
        errors: usize,
        first_error: Option<String>,
        deadline_misses: usize,
        retries: usize,
        wall_s: f64,
        cfg: &LoadgenConfig,
    ) -> Report {
        let mut lat: Vec<f64> = records.iter().map(|r| r.latency_ms).collect();
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        // Empty-sample guard: `percentile_nearest_sorted` returns NaN
        // on an empty slice, which would flow into the JSON artifact —
        // an all-fail run reports 0 percentiles and its first error.
        let pct = |p: f64| if lat.is_empty() { 0.0 } else { percentile_nearest_sorted(&lat, p) };
        Report {
            rps: records.len() as f64 / wall_s.max(1e-9),
            p50_ms: pct(50.0),
            p90_ms: pct(90.0),
            p99_ms: pct(99.0),
            degraded: records.iter().filter(|r| r.degraded).count(),
            records,
            errors,
            first_error,
            deadline_misses,
            retries,
            wall_s,
            server_metrics: None,
            cfg: cfg.clone(),
        }
    }

    pub fn summary(&self) -> String {
        let attempted = self.records.len() + self.errors + self.deadline_misses;
        let mut s = format!(
            "loadgen: fleet {} x {} requests ({}) -> {} ok, errors {}, wall {:.3}s, {:.0} req/s\n\
             latency p50 {:.2} ms  p90 {:.2} ms  p99 {:.2} ms\n\
             overload: deadline misses {} ({:.1}%)  degraded serves {}  retries {}",
            self.cfg.fleet,
            self.cfg.requests_per_device,
            self.mode(),
            self.records.len(),
            self.errors,
            self.wall_s,
            self.rps,
            self.p50_ms,
            self.p90_ms,
            self.p99_ms,
            self.deadline_misses,
            100.0 * self.deadline_misses as f64 / attempted.max(1) as f64,
            self.degraded,
            self.retries
        );
        if let Some(e) = &self.first_error {
            s.push_str(&format!("\nfirst error: {e}"));
        }
        s
    }

    fn mode(&self) -> String {
        if self.cfg.open_rps > 0.0 {
            format!("open-loop {:.0} req/s", self.cfg.open_rps)
        } else {
            "closed-loop".to_string()
        }
    }

    /// Machine-readable artifact (`--out x.json`).  All numbers finite:
    /// empty distributions are zeros, never NaN.
    pub fn to_json(&self) -> Value {
        let finite = |v: f64| if v.is_finite() { Value::Num(v) } else { Value::Null };
        Value::obj(vec![
            ("fleet", Value::from(self.cfg.fleet)),
            ("requests_per_device", Value::from(self.cfg.requests_per_device)),
            ("seed", Value::from(self.cfg.seed as i64)),
            ("think_ms", Value::from(self.cfg.think_ms as i64)),
            ("precision", Value::from(self.cfg.precision as i64)),
            ("open_rps", finite(self.cfg.open_rps)),
            ("mode", Value::from(self.mode().as_str())),
            ("ok", Value::from(self.records.len())),
            ("errors", Value::from(self.errors)),
            ("deadline_misses", Value::from(self.deadline_misses)),
            ("degraded", Value::from(self.degraded)),
            ("retries", Value::from(self.retries)),
            (
                "first_error",
                match &self.first_error {
                    Some(e) => Value::from(e.as_str()),
                    None => Value::Null,
                },
            ),
            ("wall_s", finite(self.wall_s)),
            ("rps", finite(self.rps)),
            ("p50_ms", finite(self.p50_ms)),
            ("p90_ms", finite(self.p90_ms)),
            ("p99_ms", finite(self.p99_ms)),
            (
                "server_metrics",
                self.server_metrics.clone().unwrap_or(Value::Null),
            ),
        ])
    }

    /// Text latency histogram (16 linear buckets) for logging/upload.
    pub fn histogram(&self) -> String {
        let lat: Vec<f64> = self.records.iter().map(|r| r.latency_ms).collect();
        let mut out = format!(
            "# pbsp loadgen latency histogram (ms)\n\
             # fleet {} x {} requests ({}), seed {}, p{}\n\
             # n {}  errors {}  p50 {:.3}  p90 {:.3}  p99 {:.3}  {:.0} req/s\n",
            self.cfg.fleet,
            self.cfg.requests_per_device,
            self.mode(),
            self.cfg.seed,
            self.cfg.precision,
            lat.len(),
            self.errors,
            self.p50_ms,
            self.p90_ms,
            self.p99_ms,
            self.rps
        );
        if lat.is_empty() {
            return out;
        }
        let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
        for &v in &lat {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        let buckets = 16usize;
        let width = ((hi - lo) / buckets as f64).max(1e-9);
        let mut counts = vec![0usize; buckets];
        for &v in &lat {
            let b = (((v - lo) / width) as usize).min(buckets - 1);
            counts[b] += 1;
        }
        let peak = counts.iter().copied().max().unwrap_or(1).max(1);
        for (b, &c) in counts.iter().enumerate() {
            let bar = "#".repeat(c * 40 / peak);
            out.push_str(&format!(
                "{:>9.3}-{:<9.3} ms | {:>6} {bar}\n",
                lo + b as f64 * width,
                lo + (b + 1) as f64 * width,
                c
            ));
        }
        out
    }
}

/// Per-device state, owned by whichever worker its shard lands on.
struct DeviceState {
    device: usize,
    rng: Pcg32,
    think_rng: Pcg32,
    backoff: Backoff,
    client: Option<Client>,
    seq: usize,
    /// Earliest time the next request may launch.
    next_at: Instant,
    records: Vec<DeviceRecord>,
    errors: usize,
    deadline_misses: usize,
    retries: usize,
    first_error: Option<String>,
}

/// Run a fleet against a listening frontend.  Loads the artifact tree
/// client-side (devices own their sensor data), shards devices onto
/// bounded worker threads, merges records in (device, seq) order.
pub fn run(addr: SocketAddr, cfg: &LoadgenConfig) -> Result<Report> {
    if cfg.fleet == 0 || cfg.requests_per_device == 0 {
        bail!("fleet and requests_per_device must be positive");
    }
    if !cfg.open_rps.is_finite() || cfg.open_rps < 0.0 {
        bail!("open_rps must be a finite non-negative rate");
    }
    let dir = crate::artifacts_dir()?;
    let manifest = Manifest::load(&dir)?;
    let datasets: Vec<Dataset> = manifest
        .models
        .iter()
        .map(|m| Dataset::load(manifest.data_dir(), &m.dataset, "test"))
        .collect::<Result<_>>()?;
    for (m, ds) in manifest.models.iter().zip(&datasets) {
        if ds.is_empty() {
            bail!("model {:?}: empty test set", m.name);
        }
    }
    let names: Arc<Vec<String>> =
        Arc::new(manifest.models.iter().map(|m| m.name.clone()).collect());
    let datasets = Arc::new(datasets);

    let workers = cfg.workers();
    let t0 = Instant::now();
    // Round-robin device -> worker assignment; each worker owns its
    // devices' full state, so no cross-thread synchronization at all.
    let handles: Vec<_> = (0..workers)
        .map(|w| {
            let names = Arc::clone(&names);
            let datasets = Arc::clone(&datasets);
            let cfg = cfg.clone();
            let devices: Vec<usize> = (0..cfg.fleet).filter(|d| d % workers == w).collect();
            std::thread::Builder::new()
                .name(format!("pbsp-lgworker-{w}"))
                .spawn(move || worker_loop(addr, t0, devices, &names, &datasets, &cfg))
                .context("spawn loadgen worker")
        })
        .collect::<Result<_>>()?;
    let mut records = Vec::with_capacity(cfg.fleet * cfg.requests_per_device);
    let mut errors = 0usize;
    let mut deadline_misses = 0usize;
    let mut retries = 0usize;
    let mut first_error: Option<String> = None;
    for h in handles {
        let t = h.join().map_err(|_| anyhow!("loadgen worker panicked"))?;
        records.extend(t.records);
        errors += t.errors;
        deadline_misses += t.deadline_misses;
        retries += t.retries;
        if first_error.is_none() {
            first_error = t.first_error;
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    records.sort_by_key(|r: &DeviceRecord| (r.device, r.seq));
    let mut report = Report::new(records, errors, first_error, deadline_misses, retries, wall_s, cfg);
    // Scrape the frontend's /metrics while it is still listening so the
    // JSON artifact carries the server-side view of the run (`verify`
    // reconciles it against the fleet's own counts).  Best-effort: a
    // failed scrape leaves the field null, it never fails a done run.
    report.server_metrics = scrape_metrics(addr);
    Ok(report)
}

/// Best-effort `/metrics` scrape (`None` on any failure).  Exposed so a
/// chaos-proxied run can re-scrape the *direct* server address — the
/// fleet's own scrape would ride the proxy and might get faulted.
pub fn scrape_metrics(addr: SocketAddr) -> Option<Value> {
    Client::connect(addr)
        .and_then(|mut c| c.get("/metrics"))
        .ok()
        .and_then(|(status, text)| if status == 200 { Value::parse(&text).ok() } else { None })
}

/// What one worker hands back when it joins.
struct WorkerTotals {
    records: Vec<DeviceRecord>,
    errors: usize,
    deadline_misses: usize,
    retries: usize,
    first_error: Option<String>,
}

/// One worker: interleave its devices by `next_at` schedule, running
/// one request per due device per pass.
fn worker_loop(
    addr: SocketAddr,
    t0: Instant,
    devices: Vec<usize>,
    names: &[String],
    datasets: &[Dataset],
    cfg: &LoadgenConfig,
) -> WorkerTotals {
    // Open-loop: the fleet-wide schedule is `open_rps` evenly spaced,
    // device-interleaved — device d launches at t0 + (d + k*fleet)/rate.
    let interval = if cfg.open_rps > 0.0 {
        Some(Duration::from_secs_f64(cfg.fleet as f64 / cfg.open_rps))
    } else {
        None
    };
    let mut states: Vec<DeviceState> = devices
        .into_iter()
        .map(|d| DeviceState {
            device: d,
            rng: Pcg32::new(cfg.seed, d as u64),
            think_rng: Pcg32::new(cfg.seed, (cfg.fleet + d) as u64),
            backoff: Backoff::new(Pcg32::new(cfg.seed, (2 * cfg.fleet + d) as u64)),
            client: None,
            seq: 0,
            next_at: match interval {
                Some(iv) => t0 + iv.mul_f64(d as f64 / cfg.fleet as f64),
                None => t0,
            },
            records: Vec::with_capacity(cfg.requests_per_device),
            errors: 0,
            deadline_misses: 0,
            retries: 0,
            first_error: None,
        })
        .collect();
    loop {
        let now = Instant::now();
        let mut all_done = true;
        let mut earliest: Option<Instant> = None;
        for dev in states.iter_mut() {
            if dev.seq >= cfg.requests_per_device {
                continue;
            }
            all_done = false;
            if dev.next_at > now {
                earliest = Some(earliest.map_or(dev.next_at, |e| e.min(dev.next_at)));
                continue;
            }
            run_one(addr, dev, names, datasets, cfg);
            // Schedule the follow-up.
            match interval {
                // Open-loop: fixed cadence from the *scheduled* slot, so
                // a slow server accumulates visible queueing delay.
                Some(iv) => dev.next_at += iv,
                None => {
                    dev.next_at = Instant::now();
                    if cfg.think_ms > 0 {
                        let think = dev.think_rng.below(cfg.think_ms + 1);
                        dev.next_at += Duration::from_millis(think);
                    }
                }
            }
        }
        if all_done {
            break;
        }
        if let Some(e) = earliest {
            let now = Instant::now();
            if e > now {
                // Bounded nap so newly-due devices are picked up promptly.
                std::thread::sleep((e - now).min(Duration::from_millis(2)));
            }
        }
    }
    let mut totals = WorkerTotals {
        records: Vec::new(),
        errors: 0,
        deadline_misses: 0,
        retries: 0,
        first_error: None,
    };
    for dev in states {
        totals.records.extend(dev.records);
        totals.errors += dev.errors;
        totals.deadline_misses += dev.deadline_misses;
        totals.retries += dev.retries;
        if totals.first_error.is_none() {
            totals.first_error = dev.first_error;
        }
    }
    totals
}

/// Execute one request for one device.  Open-loop latency is measured
/// from the scheduled slot (`next_at`), closed-loop from launch.
fn run_one(
    addr: SocketAddr,
    dev: &mut DeviceState,
    names: &[String],
    datasets: &[Dataset],
    cfg: &LoadgenConfig,
) {
    let seq = dev.seq;
    dev.seq += 1;
    let (model, sample) = draw_request(&mut dev.rng, datasets);
    let path = format!("/v1/score/{}/p{}", names[model], cfg.precision);
    let body = score_body(&datasets[model].x[sample]);
    let mut headers: Vec<(&str, String)> = Vec::new();
    if cfg.deadline_ms > 0 {
        headers.push(("x-deadline-ms", cfg.deadline_ms.to_string()));
    }
    let t_start = if cfg.open_rps > 0.0 { dev.next_at } else { Instant::now() };
    let outcome = post_with_retry(
        &mut dev.client,
        addr,
        &path,
        &body,
        &headers,
        cfg.attempts.max(1),
        &mut dev.backoff,
        &mut dev.retries,
    );
    match outcome {
        Ok(PostOutcome::Ok(text)) => match parse_response(&text) {
            Ok((scores, precision, degraded)) => dev.records.push(DeviceRecord {
                device: dev.device,
                seq,
                model,
                sample,
                scores,
                latency_ms: t_start.elapsed().as_secs_f64() * 1e3,
                precision,
                degraded,
            }),
            Err(e) => dev.fail(format!("device {}: bad response: {e:#}", dev.device)),
        },
        // The server shed the request past its deadline: an overload
        // outcome the report counts separately, not a device error.
        Ok(PostOutcome::DeadlineMiss) => dev.deadline_misses += 1,
        Err(e) => dev.fail(format!("device {}: {e:#}", dev.device)),
    }
}

impl DeviceState {
    fn fail(&mut self, msg: String) {
        self.errors += 1;
        if self.first_error.is_none() {
            self.first_error = Some(msg);
        }
    }
}

/// The per-request draw, isolated so its order is pinned by tests: one
/// model draw, one sample draw — nothing else touches the request
/// stream (think-times and scheduling use a separate stream).
fn draw_request(rng: &mut Pcg32, datasets: &[Dataset]) -> (usize, usize) {
    let model = rng.below(datasets.len() as u64) as usize;
    let sample = rng.below(datasets[model].len() as u64) as usize;
    (model, sample)
}

/// Capped decorrelated-jitter backoff: each delay is drawn uniformly
/// from `[base, 3 * previous]`, clamped to `cap`, with the server's
/// `Retry-After` (seconds, also clamped to `cap`) as a floor.  The
/// draws come from the device's *third* PCG stream, so retry timing
/// never perturbs the request draws — the fleet's request sequence
/// stays a pure function of (seed, fleet, requests_per_device) even
/// under chaos.
struct Backoff {
    rng: Pcg32,
    prev: Duration,
}

impl Backoff {
    const BASE: Duration = Duration::from_millis(10);
    const CAP: Duration = Duration::from_millis(500);

    fn new(rng: Pcg32) -> Backoff {
        Backoff { rng, prev: Self::BASE }
    }

    fn next_delay(&mut self, retry_after_s: Option<u64>) -> Duration {
        let hi = (self.prev * 3).min(Self::CAP).max(Self::BASE);
        let span_ms = (hi.as_millis() as u64).saturating_sub(Self::BASE.as_millis() as u64);
        let jitter = Duration::from_millis(if span_ms == 0 { 0 } else { self.rng.below(span_ms + 1) });
        let mut delay = (Self::BASE + jitter).min(Self::CAP);
        if let Some(s) = retry_after_s {
            delay = delay.max(Duration::from_secs(s).min(Self::CAP));
        }
        self.prev = delay;
        delay
    }
}

/// How one POST resolved, retries included.
enum PostOutcome {
    /// 200 with its body.
    Ok(String),
    /// The server 504'd: the request's deadline expired before (or in)
    /// the compute pool.  Never retried — the budget is already spent.
    DeadlineMiss,
}

/// POST with retries that each *consume an attempt* — transport
/// failures (including a failed reconnect during server churn; safe
/// because scoring is read-only) and the server's 503 backpressure
/// refusals, which back off with seeded decorrelated jitter honouring
/// `Retry-After`.  504 resolves immediately as a deadline miss; any
/// other non-200 is a deterministic failure and surfaces at once.
#[allow(clippy::too_many_arguments)]
fn post_with_retry(
    client: &mut Option<Client>,
    addr: SocketAddr,
    path: &str,
    body: &str,
    headers: &[(&str, String)],
    attempts: usize,
    backoff: &mut Backoff,
    retries: &mut usize,
) -> Result<PostOutcome> {
    let mut last: Option<anyhow::Error> = None;
    for attempt in 0..attempts {
        if attempt > 0 {
            *retries += 1;
        }
        if client.is_none() {
            match Client::connect(addr) {
                Ok(c) => *client = Some(c),
                Err(e) => {
                    // A transient connect failure consumes this attempt
                    // instead of propagating out of the retry loop.
                    last = Some(e);
                    std::thread::sleep(backoff.next_delay(None));
                    continue;
                }
            }
        }
        let c = client.as_mut().expect("client just connected");
        match c.request_meta("POST", path, Some(body), headers) {
            Ok((200, _h, text)) => return Ok(PostOutcome::Ok(text)),
            Ok((504, _h, _text)) => return Ok(PostOutcome::DeadlineMiss),
            Ok((503, h, text)) => {
                // Backpressure: retry after the server's hint (if any).
                // The rejected-busy 503 closes the connection server-side;
                // a dead keep-alive surfaces as a transport error on the
                // next attempt and reconnects there.
                last = Some(anyhow!("HTTP 503: {text}"));
                let ra = h.get("retry-after").and_then(|v| v.trim().parse::<u64>().ok());
                std::thread::sleep(backoff.next_delay(ra));
            }
            Ok((status, _h, text)) => bail!("HTTP {status}: {text}"),
            Err(e) => {
                last = Some(e);
                *client = None; // dead connection: reconnect next attempt
                std::thread::sleep(backoff.next_delay(None));
            }
        }
    }
    match last {
        Some(e) => Err(e.context(format!("request failed after {attempts} attempts"))),
        None => bail!("request failed after {attempts} attempts"),
    }
}

fn score_body(x: &[f32]) -> String {
    let row = Value::Arr(x.iter().map(|&v| Value::Num(v as f64)).collect());
    Value::obj(vec![("x", row)]).to_string()
}

/// Decode a 200 score response: (scores, served precision, degraded).
/// The served precision comes from the response's `variant` label —
/// under brownout it may be lower than the requested one, and `verify`
/// replays against it.
fn parse_response(text: &str) -> Result<(Vec<f64>, u32, bool)> {
    let v = Value::parse(text)?;
    let scores = v.get("scores")?.as_f64_vec()?;
    let variant = v.get("variant")?.as_str()?;
    let precision = variant
        .strip_prefix('p')
        .and_then(|d| d.parse::<u32>().ok())
        .ok_or_else(|| anyhow!("unparseable served variant {variant:?}"))?;
    let degraded = match v.opt("degraded") {
        Some(b) => b.as_bool()?,
        None => false,
    };
    Ok((scores, precision, degraded))
}

/// Replay every fleet record through in-process [`Service::scores`] and
/// require the HTTP-served scores to be bit-identical (the fleet JSON
/// round-trips f64 exactly, so any drift is a real divergence).  Each
/// record replays at the precision the server *claimed* to serve it at
/// — a brownout-degraded response must match the lower variant exactly,
/// so a mislabelled degradation fails here.  With an ISS-backed service
/// this pins the whole chain — HTTP frontend → reactor → dynamic
/// batcher → batched lockstep ISS — against a direct in-process run.
pub fn verify(svc: &Service, report: &Report) -> Result<usize> {
    use crate::coordinator::router::Key;
    use std::collections::BTreeMap;
    // Group records per (model, served precision) so each replay is one
    // bulk batch at the right variant.
    let mut groups: BTreeMap<(usize, u32), Vec<&DeviceRecord>> = BTreeMap::new();
    for r in &report.records {
        groups.entry((r.model, r.precision)).or_default().push(r);
    }
    let mut checked = 0usize;
    for (&(mi, precision), recs) in &groups {
        let model = &svc.models[mi];
        let ds = Dataset::load(svc.manifest.data_dir(), &model.dataset, "test")?;
        let xs: Vec<Vec<f32>> = recs.iter().map(|r| ds.x[r.sample].clone()).collect();
        let got = svc.scores(&Key::precision(&model.name, precision), &xs)?;
        for (r, g) in recs.iter().zip(&got) {
            if &r.scores != g {
                bail!(
                    "verify: device {} seq {} ({} sample {} p{}{}): served {:?} vs in-process {:?}",
                    r.device,
                    r.seq,
                    model.name,
                    r.sample,
                    precision,
                    if r.degraded { ", degraded" } else { "" },
                    r.scores,
                    g
                );
            }
        }
        checked += recs.len();
    }
    // Counter reconciliation: every successful fleet record rode one
    // HTTP request, so the server must have counted at least that many
    // (keep-alive probes, retries and the /metrics scrape itself only
    // push the server-side count higher).  Same direction for the
    // overload counters: each client-observed degraded serve / 504 was
    // counted server-side, and the server may have seen more (responses
    // the chaos proxy cut off before the client read them).
    if let Some(sm) = &report.server_metrics {
        let server = sm.get("server")?;
        let served = server.get("http_requests")?.as_i64()?;
        if (served as usize) < report.records.len() {
            bail!(
                "verify: server counted {served} http requests but the fleet recorded {} \
                 successes — counters do not reconcile",
                report.records.len()
            );
        }
        if let Ok(d) = server.get("degraded").and_then(|v| v.as_i64()) {
            if (d as usize) < report.degraded {
                bail!(
                    "verify: server counted {d} degraded serves but the fleet recorded {} \
                     — counters do not reconcile",
                    report.degraded
                );
            }
        }
        let shed = server.get("deadline_shed").and_then(|v| v.as_i64()).unwrap_or(0)
            + server.get("deadline_shed_batch").and_then(|v| v.as_i64()).unwrap_or(0);
        if (shed as usize) < report.deadline_misses {
            bail!(
                "verify: server counted {shed} deadline sheds but the fleet saw {} 504s \
                 — counters do not reconcile",
                report.deadline_misses
            );
        }
    }
    Ok(checked)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::percentile_nearest;

    fn empty_report(cfg: &LoadgenConfig) -> Report {
        Report::new(Vec::new(), 7, Some("device 0: connect refused".into()), 0, 0, 0.25, cfg)
    }

    #[test]
    fn score_body_roundtrips_f32_exactly() {
        let x = [0.1f32, -3.5, 2.0, 1e-7];
        let body = score_body(&x);
        let v = Value::parse(&body).unwrap();
        let back: Vec<f32> =
            v.get("x").unwrap().as_f64_vec().unwrap().into_iter().map(|f| f as f32).collect();
        assert_eq!(back, x, "JSON number round-trip must be exact for f32 inputs");
    }

    #[test]
    fn device_sequences_are_deterministic_and_distinct() {
        let draw = |seed, device: usize| {
            let mut rng = Pcg32::new(seed, device as u64);
            (0..16).map(|_| (rng.below(6), rng.below(40))).collect::<Vec<_>>()
        };
        assert_eq!(draw(1, 0), draw(1, 0));
        assert_ne!(draw(1, 0), draw(1, 1));
        assert_ne!(draw(1, 0), draw(2, 0));
    }

    /// Regression (ISSUE 7): an all-fail run must report finite (zero)
    /// percentiles and carry its first error — not NaN into the JSON
    /// artifact.
    #[test]
    fn all_fail_report_has_no_nan() {
        let cfg = LoadgenConfig::default();
        let r = empty_report(&cfg);
        assert_eq!(r.p50_ms, 0.0);
        assert_eq!(r.p90_ms, 0.0);
        assert_eq!(r.p99_ms, 0.0);
        assert!(r.rps == 0.0);
        let json = r.to_json().to_string();
        assert!(!json.contains("NaN") && !json.contains("nan"), "artifact leaked NaN: {json}");
        // The artifact must round-trip as valid JSON and name the cause.
        let back = Value::parse(&json).unwrap();
        assert_eq!(back.get("errors").unwrap().as_i64().unwrap(), 7);
        assert_eq!(back.get("p50_ms").unwrap().as_f64().unwrap(), 0.0);
        assert!(back.get("first_error").unwrap().as_str().unwrap().contains("connect"));
        assert!(r.summary().contains("first error"), "summary must surface the first error");
        // An unscraped report still carries the key (null), so the CI
        // artifact schema is stable whether or not the scrape landed.
        assert!(back.opt("server_metrics").is_some(), "artifact must carry server_metrics");
    }

    /// Regression (ISSUE 7): a refused `Client::connect` consumes a
    /// retry attempt (and yields an error) instead of propagating out
    /// of the retry loop with `?`.
    #[test]
    fn connect_refusal_consumes_attempts() {
        // Bind + drop: the ephemeral port is (almost surely) refusing.
        let addr = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap()
        };
        let mut client: Option<Client> = None;
        let mut backoff = Backoff::new(Pcg32::new(1, 0));
        let mut retries = 0usize;
        let err = post_with_retry(
            &mut client,
            addr,
            "/v1/score/m/p8",
            "{}",
            &[],
            2,
            &mut backoff,
            &mut retries,
        )
        .unwrap_err();
        let msg = format!("{err:#}");
        assert!(
            msg.contains("after 2 attempts"),
            "connect refusal must burn through the retry budget, got: {msg}"
        );
        assert!(client.is_none());
        assert_eq!(retries, 1, "two attempts = one counted retry");
    }

    /// Backoff is a pure function of its PCG stream: same seed, same
    /// delays; always within [base, cap]; `Retry-After` floors the
    /// delay (clamped to the cap so a hostile hint can't stall a
    /// device).
    #[test]
    fn backoff_is_seeded_capped_and_honours_retry_after() {
        let seq = |seed: u64| {
            let mut b = Backoff::new(Pcg32::new(seed, 5));
            (0..8).map(|_| b.next_delay(None).as_millis() as u64).collect::<Vec<_>>()
        };
        assert_eq!(seq(3), seq(3), "backoff must be deterministic per seed");
        assert_ne!(seq(3), seq(4), "distinct seeds should jitter differently");
        for ms in seq(3) {
            assert!((10..=500).contains(&ms), "delay {ms}ms outside [base, cap]");
        }
        let mut b = Backoff::new(Pcg32::new(1, 1));
        // Retry-After of 1s exceeds the 500ms cap -> clamped exactly.
        assert_eq!(b.next_delay(Some(1)).as_millis(), 500);
    }

    #[test]
    fn response_decode_reads_served_precision_and_degraded() {
        let plain = r#"{"model":"m","variant":"p8","scores":[1.5,2.0],"prediction":1}"#;
        let (scores, precision, degraded) = parse_response(plain).unwrap();
        assert_eq!(scores, vec![1.5, 2.0]);
        assert_eq!(precision, 8);
        assert!(!degraded);

        let browned =
            r#"{"model":"m","variant":"p4","degraded":true,"requested":"p8","scores":[1.0]}"#;
        let (_, precision, degraded) = parse_response(browned).unwrap();
        assert_eq!(precision, 4, "must record the precision actually served");
        assert!(degraded);

        // float is never served by the fleet path; an unparseable
        // variant label is a hard error, not a silent p-default.
        assert!(parse_response(r#"{"variant":"float","scores":[1.0]}"#).is_err());
    }

    /// The request draw stream is independent of arrival mode and
    /// sharding: (model, sample) sequences depend only on (seed, device).
    #[test]
    fn open_loop_schedule_preserves_draw_order() {
        let seqs = |seed: u64| {
            let mut rng = Pcg32::new(seed, 3);
            (0..32).map(|_| (rng.below(6), rng.below(40))).collect::<Vec<_>>()
        };
        // draw_request consumes exactly two draws per request — the
        // whole schedule/think machinery never touches this stream.
        assert_eq!(seqs(9), seqs(9));
    }

    #[test]
    fn histogram_renders_counts() {
        let cfg = LoadgenConfig::default();
        let records: Vec<DeviceRecord> = (0..10)
            .map(|i| DeviceRecord {
                device: 0,
                seq: i,
                model: 0,
                sample: i,
                scores: vec![0.0],
                latency_ms: (i + 1) as f64,
                precision: 8,
                degraded: false,
            })
            .collect();
        let lat: Vec<f64> = records.iter().map(|r| r.latency_ms).collect();
        let report = Report {
            rps: 10.0,
            p50_ms: percentile_nearest(&lat, 50.0),
            p90_ms: percentile_nearest(&lat, 90.0),
            p99_ms: percentile_nearest(&lat, 99.0),
            records,
            errors: 0,
            first_error: None,
            deadline_misses: 0,
            degraded: 0,
            retries: 0,
            wall_s: 1.0,
            server_metrics: None,
            cfg,
        };
        let h = report.histogram();
        assert!(h.contains("# n 10  errors 0"));
        assert!(h.lines().count() > 10, "16 buckets expected:\n{h}");
        assert!(report.summary().contains("errors 0"));
    }

    #[test]
    fn worker_sharding_covers_every_device() {
        for (fleet, workers) in [(1usize, 1usize), (10, 3), (64, 64), (1000, 64)] {
            let mut seen = vec![false; fleet];
            for w in 0..workers {
                for d in (0..fleet).filter(|d| d % workers == w) {
                    assert!(!seen[d], "device {d} assigned twice");
                    seen[d] = true;
                }
            }
            assert!(seen.iter().all(|&s| s), "all devices covered ({fleet}/{workers})");
        }
    }
}
