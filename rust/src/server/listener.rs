//! The HTTP frontend handle: configuration, shared metrics, and the
//! `Server` lifecycle around the event-driven reactor
//! (`server::reactor`).
//!
//! Concurrency model (since the reactor rework): **one** reactor thread
//! owns every connection socket non-blocking and multiplexes them with
//! `poll(2)` (`util::poll`); requests are handed to the
//! `util::threadpool` compute pool only once fully buffered.
//! `http_threads` therefore sizes the *compute* pool — connection
//! concurrency is bounded separately by `max_connections`, so
//! thousands of mostly-idle keep-alive devices fit on a handful of
//! threads.  Backpressure is visible at both levels: connections past
//! `max_connections` get `503` + `Retry-After` (written asynchronously
//! — a refused client that never reads can never stall the accept
//! path), and requests past `max_queued` in-flight get `503` +
//! `Retry-After` on their healthy keep-alive connection.
//!
//! Shutdown is: flip the flag, wake the reactor, join it (it drains
//! in-flight requests within a bounded grace period), drop the pool.
//! Idempotent; also runs on drop.

use std::net::{SocketAddr, TcpListener};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use super::reactor::{self, ReactorConfig, ReactorShared, TraceSink};
use crate::coordinator::service::Service;
use crate::util::json::Value;
use crate::util::threadpool::{self, ThreadPool};

/// How long shutdown waits for in-flight requests to finish and their
/// responses to drain before force-closing the remaining sockets.
const SHUTDOWN_GRACE: Duration = Duration::from_secs(5);

#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Bind address; port 0 picks an ephemeral port (tests, benches).
    pub addr: String,
    /// Compute pool size (concurrent request *handlers*).  Not a
    /// connection cap — see `max_connections`.
    pub http_threads: usize,
    /// Idle keep-alive budget per connection before the server closes it.
    pub keep_alive_ms: u64,
    /// Admission cap on concurrently open connections; arrivals past it
    /// are refused with `503` + `Retry-After` (`rejected_busy`).
    pub max_connections: usize,
    /// Cap on requests in flight on the compute pool; requests past it
    /// are refused with `503` + `Retry-After` (`rejected_queue`)
    /// without dropping the connection.
    pub max_queued: usize,
    /// Mid-message deadline: a request whose first byte has arrived
    /// must complete within this (slow-loris guard).
    pub msg_deadline_ms: u64,
    /// Evict a connection whose pending response makes no write
    /// progress for this long (peer stopped reading).
    pub write_stall_ms: u64,
    /// Emit a structured trace span for every Nth pool-dispatched
    /// request (0 disables tracing).
    pub trace_sample: u64,
    /// Where sampled spans go as JSON lines; `None` writes to stderr.
    pub trace_log: Option<String>,
    /// Deadline budget applied to requests that carry no `X-Deadline-Ms`
    /// header (0 = no default; only the header arms a deadline).
    pub default_deadline_ms: u64,
    /// Brownout high watermark on in-flight requests: at or above it the
    /// router downshifts eligible score requests to the next-lower
    /// precision variant (0 disables brownout).
    pub brownout_high: usize,
    /// Brownout low watermark: brownout clears once in-flight falls to
    /// or below it (defaults to `brownout_high / 2` when 0).
    pub brownout_low: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:0".to_string(),
            http_threads: threadpool::default_threads().max(8),
            keep_alive_ms: 2_000,
            max_connections: 4_096,
            max_queued: 1_024,
            msg_deadline_ms: 30_000,
            write_stall_ms: 10_000,
            trace_sample: 0,
            trace_log: None,
            default_deadline_ms: 0,
            brownout_high: 0,
            brownout_low: 0,
        }
    }
}

/// Server-side counters (the coordinator keeps its own — `/metrics`
/// reports both).  Plain atomics: incremented from the reactor and pool
/// workers, snapshot without locking.
#[derive(Debug, Default)]
pub struct ServerMetrics {
    /// Connections accepted and admitted (cumulative).
    pub connections: AtomicU64,
    /// Currently-open connections (gauge, maintained by the reactor).
    pub open_connections: AtomicU64,
    pub http_requests: AtomicU64,
    pub responses_2xx: AtomicU64,
    pub responses_4xx: AtomicU64,
    pub responses_5xx: AtomicU64,
    /// Responses outside the 2xx/4xx/5xx classes (1xx/3xx) — tracked
    /// separately so `responses_5xx` counts only real server errors.
    pub responses_other: AtomicU64,
    pub samples_scored: AtomicU64,
    /// Connections refused with 503 at the `max_connections` admission
    /// gate.
    pub rejected_busy: AtomicU64,
    /// Requests refused with 503 at the `max_queued` compute gate (the
    /// connection itself is kept).
    pub rejected_queue: AtomicU64,
    /// Connections reaped by the deadline sweep past their keep-alive
    /// budget (no partial message buffered).
    pub evicted_idle: AtomicU64,
    /// Connections cut off mid-message by the slow-loris deadline (the
    /// sweep queues a best-effort 400 first).
    pub evicted_read: AtomicU64,
    /// Connections evicted because a pending response made no write
    /// progress for `write_stall_ms` (peer stopped reading).
    pub evicted_write: AtomicU64,
    /// Requests shed with 504 at pool pickup: their deadline had already
    /// passed before the handler ran (no compute was spent).
    pub deadline_shed: AtomicU64,
    /// Requests shed with 504 inside the coordinator's dynamic batcher
    /// (their deadline passed while they waited to be batched).
    pub deadline_shed_batch: AtomicU64,
    /// Score responses served at a lower precision than requested
    /// because the server was in brownout.
    pub degraded: AtomicU64,
    /// Transitions into brownout (hysteresis: high watermark crossed).
    pub brownout_entered: AtomicU64,
    /// State: currently above the brownout watermarks (drives the
    /// degradation router and `/readyz`).
    pub brownout: AtomicBool,
    /// State: shutdown drain has begun (`/readyz` turns 503).
    pub draining: AtomicBool,
    /// Gauge: requests currently on (or queued for) the compute pool,
    /// published by the reactor once per loop round.
    pub inflight: AtomicU64,
    /// The configured `max_connections` / `max_queued`, published by
    /// `Server::start` so `/readyz` can judge over-capacity.
    pub limit_connections: AtomicU64,
    pub limit_queued: AtomicU64,
}

impl ServerMetrics {
    pub(crate) fn count_status(&self, status: u16) {
        let counter = match status / 100 {
            2 => &self.responses_2xx,
            4 => &self.responses_4xx,
            5 => &self.responses_5xx,
            // 1xx/3xx are not server errors; bucketing them into 5xx
            // (as the thread-per-connection listener did) made
            // /metrics unreconcilable.
            _ => &self.responses_other,
        };
        counter.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add_scored(&self, n: u64) {
        self.samples_scored.fetch_add(n, Ordering::Relaxed);
    }

    pub fn to_json(&self) -> Value {
        let get = |c: &AtomicU64| Value::from(c.load(Ordering::Relaxed) as i64);
        Value::obj(vec![
            ("connections", get(&self.connections)),
            ("open_connections", get(&self.open_connections)),
            ("http_requests", get(&self.http_requests)),
            ("responses_2xx", get(&self.responses_2xx)),
            ("responses_4xx", get(&self.responses_4xx)),
            ("responses_5xx", get(&self.responses_5xx)),
            ("responses_other", get(&self.responses_other)),
            ("samples_scored", get(&self.samples_scored)),
            ("rejected_busy", get(&self.rejected_busy)),
            ("rejected_queue", get(&self.rejected_queue)),
            ("evicted_idle", get(&self.evicted_idle)),
            ("evicted_read", get(&self.evicted_read)),
            ("evicted_write", get(&self.evicted_write)),
            ("deadline_shed", get(&self.deadline_shed)),
            ("deadline_shed_batch", get(&self.deadline_shed_batch)),
            ("degraded", get(&self.degraded)),
            ("brownout_entered", get(&self.brownout_entered)),
            ("brownout", Value::from(self.brownout.load(Ordering::Relaxed))),
            ("draining", Value::from(self.draining.load(Ordering::Relaxed))),
            ("inflight", get(&self.inflight)),
        ])
    }
}

/// The running HTTP frontend.  Dropping it shuts the reactor down and
/// joins every thread.
pub struct Server {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    reactor: Option<JoinHandle<()>>,
    /// Held so in-flight compute outlives the reactor; dropped (and
    /// joined) after the reactor stops feeding it.
    pool: Option<Arc<ThreadPool>>,
    shared: Arc<ReactorShared>,
    pub metrics: Arc<ServerMetrics>,
}

impl Server {
    pub fn start(svc: Arc<Service>, cfg: ServerConfig) -> Result<Server> {
        let listener =
            TcpListener::bind(&cfg.addr).with_context(|| format!("bind {}", cfg.addr))?;
        listener.set_nonblocking(true).context("set_nonblocking")?;
        let addr = listener.local_addr().context("local_addr")?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let metrics = Arc::new(ServerMetrics::default());
        metrics.limit_connections.store(cfg.max_connections.max(1) as u64, Ordering::Relaxed);
        metrics.limit_queued.store(cfg.max_queued.max(1) as u64, Ordering::Relaxed);
        let pool = Arc::new(ThreadPool::new(cfg.http_threads.max(1)));
        let shared = Arc::new(ReactorShared::new()?);
        let rcfg = ReactorConfig {
            keep_alive: Duration::from_millis(cfg.keep_alive_ms),
            msg_deadline: Duration::from_millis(cfg.msg_deadline_ms),
            write_stall: Duration::from_millis(cfg.write_stall_ms),
            max_connections: cfg.max_connections.max(1),
            max_queued: cfg.max_queued.max(1),
            shutdown_grace: SHUTDOWN_GRACE,
            trace_sample: cfg.trace_sample,
            default_deadline_ms: cfg.default_deadline_ms,
            brownout_high: cfg.brownout_high,
            brownout_low: if cfg.brownout_low == 0 && cfg.brownout_high > 0 {
                cfg.brownout_high / 2
            } else {
                cfg.brownout_low
            },
        };
        let sink = if cfg.trace_sample > 0 {
            Some(Arc::new(TraceSink::open(cfg.trace_log.as_deref())?))
        } else {
            None
        };
        let reactor = {
            let svc = Arc::clone(&svc);
            let pool = Arc::clone(&pool);
            let metrics = Arc::clone(&metrics);
            let shared = Arc::clone(&shared);
            let shutdown = Arc::clone(&shutdown);
            std::thread::Builder::new()
                .name("pbsp-http-reactor".into())
                .spawn(move || {
                    reactor::run(listener, svc, pool, metrics, shared, shutdown, rcfg, sink)
                })
                .context("spawn reactor")?
        };
        Ok(Server { addr, shutdown, reactor: Some(reactor), pool: Some(pool), shared, metrics })
    }

    /// The bound address (resolves port 0 to the real ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, finish in-flight requests (bounded grace), join
    /// every thread.  Idempotent; also runs on drop.
    pub fn shutdown(&mut self) {
        if self.shutdown.swap(true, Ordering::SeqCst) {
            return;
        }
        self.shared.waker.wake();
        if let Some(r) = self.reactor.take() {
            let _ = r.join();
        }
        // Dropping the pool closes its queue and joins the workers
        // (any still-running job finished before the reactor exited,
        // or its response was abandoned at the grace deadline).
        self.pool.take();
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Regression (ISSUE 7): 1xx/3xx must not inflate `responses_5xx`.
    #[test]
    fn count_status_buckets_by_class() {
        let m = ServerMetrics::default();
        for s in [200, 204, 400, 404, 500, 503, 101, 301, 304] {
            m.count_status(s);
        }
        assert_eq!(m.responses_2xx.load(Ordering::Relaxed), 2);
        assert_eq!(m.responses_4xx.load(Ordering::Relaxed), 2);
        assert_eq!(m.responses_5xx.load(Ordering::Relaxed), 2, "only real 5xx count as 5xx");
        let other = m.responses_other.load(Ordering::Relaxed);
        assert_eq!(other, 3, "1xx/3xx land in their own bucket");
    }

    #[test]
    fn metrics_json_carries_every_counter() {
        let m = ServerMetrics::default();
        m.count_status(200);
        m.add_scored(3);
        m.rejected_queue.fetch_add(1, Ordering::Relaxed);
        let v = m.to_json();
        for key in [
            "connections",
            "open_connections",
            "http_requests",
            "responses_2xx",
            "responses_4xx",
            "responses_5xx",
            "responses_other",
            "samples_scored",
            "rejected_busy",
            "rejected_queue",
            "evicted_idle",
            "evicted_read",
            "evicted_write",
            "deadline_shed",
            "deadline_shed_batch",
            "degraded",
            "brownout_entered",
            "brownout",
            "draining",
            "inflight",
        ] {
            assert!(v.opt(key).is_some(), "metrics JSON must carry {key}");
        }
        assert_eq!(v.get("responses_2xx").unwrap().as_i64().unwrap(), 1);
        assert_eq!(v.get("samples_scored").unwrap().as_i64().unwrap(), 3);
        assert_eq!(v.get("rejected_queue").unwrap().as_i64().unwrap(), 1);
    }
}
