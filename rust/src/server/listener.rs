//! The TCP listener: a bounded acceptor thread that hands each
//! connection to the shared `util::threadpool::ThreadPool`.
//!
//! Concurrency model: one pool job per *connection* (not per request) —
//! a worker owns the connection for its keep-alive lifetime, reading
//! requests in 100 ms ticks so it can notice shutdown and enforce the
//! idle budget.  `http_threads` therefore bounds concurrent
//! connections, and the bound is enforced at the acceptor: a connection
//! arriving while every worker owns one is refused immediately with
//! `503 Service Unavailable` (counted in `rejected_busy`) instead of
//! queuing unboundedly behind busy workers — overload is visible
//! backpressure, never silent starvation.  Idle connections are closed
//! at `keep_alive_ms` (the device client reconnects, see
//! `server::loadgen`).  The acceptor polls a non-blocking `accept` on a
//! short tick, so shutdown is just: flip the flag, join the acceptor,
//! drop the pool (handlers observe the flag within one read tick —
//! `HttpConn::read_message` yields every tick even mid-message).

use std::io::ErrorKind;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use super::http::{HttpConn, Outcome, Request, Response};
use super::routes;
use crate::coordinator::service::Service;
use crate::util::json::Value;
use crate::util::threadpool::{self, ThreadPool};

/// Read-tick granularity: how often a blocked handler re-checks the
/// shutdown flag and its idle budget.
const TICK_MS: u64 = 100;
/// Acceptor poll tick (also the shutdown-join latency bound).
const ACCEPT_TICK_MS: u64 = 10;
/// Socket write budget: a client that stops reading its response
/// cannot pin a worker (and its capacity slot) past this.
const WRITE_TIMEOUT: Duration = Duration::from_secs(10);

#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Bind address; port 0 picks an ephemeral port (tests, benches).
    pub addr: String,
    /// Connection-handler pool size = max concurrent connections.
    pub http_threads: usize,
    /// Idle keep-alive budget per connection before the server closes it.
    pub keep_alive_ms: u64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:0".to_string(),
            http_threads: threadpool::default_threads().max(8),
            keep_alive_ms: 2_000,
        }
    }
}

/// Server-side counters (the coordinator keeps its own — `/metrics`
/// reports both).  Plain atomics: incremented from handler threads,
/// snapshot without locking.
#[derive(Debug, Default)]
pub struct ServerMetrics {
    pub connections: AtomicU64,
    pub http_requests: AtomicU64,
    pub responses_2xx: AtomicU64,
    pub responses_4xx: AtomicU64,
    pub responses_5xx: AtomicU64,
    pub samples_scored: AtomicU64,
    /// Connections refused with 503 because every handler was busy.
    pub rejected_busy: AtomicU64,
}

impl ServerMetrics {
    fn count_status(&self, status: u16) {
        let counter = match status / 100 {
            2 => &self.responses_2xx,
            4 => &self.responses_4xx,
            _ => &self.responses_5xx,
        };
        counter.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add_scored(&self, n: u64) {
        self.samples_scored.fetch_add(n, Ordering::Relaxed);
    }

    pub fn to_json(&self) -> Value {
        let get = |c: &AtomicU64| Value::from(c.load(Ordering::Relaxed) as i64);
        Value::obj(vec![
            ("connections", get(&self.connections)),
            ("http_requests", get(&self.http_requests)),
            ("responses_2xx", get(&self.responses_2xx)),
            ("responses_4xx", get(&self.responses_4xx)),
            ("responses_5xx", get(&self.responses_5xx)),
            ("samples_scored", get(&self.samples_scored)),
            ("rejected_busy", get(&self.rejected_busy)),
        ])
    }
}

/// The running HTTP frontend.  Dropping it shuts the listener down and
/// joins every thread.
pub struct Server {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    acceptor: Option<JoinHandle<()>>,
    /// Held so connection handlers outlive the acceptor; dropped (and
    /// joined) after the acceptor stops feeding it.
    pool: Option<Arc<ThreadPool>>,
    pub metrics: Arc<ServerMetrics>,
}

impl Server {
    pub fn start(svc: Arc<Service>, cfg: ServerConfig) -> Result<Server> {
        let listener =
            TcpListener::bind(&cfg.addr).with_context(|| format!("bind {}", cfg.addr))?;
        listener.set_nonblocking(true).context("set_nonblocking")?;
        let addr = listener.local_addr().context("local_addr")?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let metrics = Arc::new(ServerMetrics::default());
        let capacity = cfg.http_threads.max(1);
        let pool = Arc::new(ThreadPool::new(capacity));
        // Connections currently owned by handlers — the acceptor's
        // admission gate (incremented here, decremented by the job).
        let active = Arc::new(AtomicU64::new(0));
        let acceptor = {
            let shutdown = Arc::clone(&shutdown);
            let metrics = Arc::clone(&metrics);
            let pool = Arc::clone(&pool);
            let active = Arc::clone(&active);
            let keep_alive_ms = cfg.keep_alive_ms;
            std::thread::Builder::new()
                .name("pbsp-http-acceptor".into())
                .spawn(move || loop {
                    if shutdown.load(Ordering::SeqCst) {
                        break;
                    }
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            // Handlers expect blocking reads with their
                            // own timeout; some platforms let accepted
                            // sockets inherit the listener's flag.
                            if stream.set_nonblocking(false).is_err() {
                                continue;
                            }
                            if active.load(Ordering::SeqCst) >= capacity as u64 {
                                // Every handler is busy: refuse fast
                                // instead of queuing behind them.  Only
                                // rejected_busy counts this — no request
                                // was read, so the response counters
                                // stay reconcilable with http_requests.
                                metrics.rejected_busy.fetch_add(1, Ordering::Relaxed);
                                let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
                                let mut conn = HttpConn::new(stream);
                                let _ = Response::error(
                                    503,
                                    "connection capacity reached; raise --http-threads",
                                )
                                .write_to(&mut conn, true);
                                continue;
                            }
                            metrics.connections.fetch_add(1, Ordering::Relaxed);
                            active.fetch_add(1, Ordering::SeqCst);
                            let svc = Arc::clone(&svc);
                            let metrics = Arc::clone(&metrics);
                            let shutdown = Arc::clone(&shutdown);
                            let active = Arc::clone(&active);
                            pool.execute(move || {
                                // Catch panics so a handler bug can
                                // neither kill the pool worker nor leak
                                // this connection's admission slot.
                                let r = catch_unwind(AssertUnwindSafe(|| {
                                    handle_connection(stream, svc, metrics, shutdown, keep_alive_ms)
                                }));
                                active.fetch_sub(1, Ordering::SeqCst);
                                if r.is_err() {
                                    eprintln!("pbsp-http: connection handler panicked");
                                }
                            });
                        }
                        Err(e) if e.kind() == ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(ACCEPT_TICK_MS));
                        }
                        Err(e) => {
                            // Transient accept failure (e.g. EMFILE):
                            // log, back off a tick, keep serving.
                            eprintln!("pbsp-http: accept error: {e}");
                            std::thread::sleep(Duration::from_millis(TICK_MS));
                        }
                    }
                })
                .context("spawn acceptor")?
        };
        Ok(Server { addr, shutdown, acceptor: Some(acceptor), pool: Some(pool), metrics })
    }

    /// The bound address (resolves port 0 to the real ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, finish in-flight requests, join every thread.
    /// Idempotent; also runs on drop.
    pub fn shutdown(&mut self) {
        if self.shutdown.swap(true, Ordering::SeqCst) {
            return;
        }
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        // Dropping the pool closes its queue and joins the handlers;
        // they notice the flag within one read tick.
        self.pool.take();
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Serve one connection for its keep-alive lifetime.
fn handle_connection(
    stream: TcpStream,
    svc: Arc<Service>,
    metrics: Arc<ServerMetrics>,
    shutdown: Arc<AtomicBool>,
    keep_alive_ms: u64,
) {
    let _ = stream.set_nodelay(true);
    if stream.set_write_timeout(Some(WRITE_TIMEOUT)).is_err() {
        return;
    }
    let mut conn = HttpConn::new(stream);
    if conn.set_read_timeout(Duration::from_millis(TICK_MS)).is_err() {
        return;
    }
    let mut idle_ms: u64 = 0;
    loop {
        match conn.read_message() {
            Ok(Outcome::Idle) => {
                if shutdown.load(Ordering::SeqCst) {
                    break;
                }
                if conn.has_partial() {
                    // Mid-message: a slow but progressing upload is
                    // governed by the connection's 30 s mid-message
                    // deadline, not the keep-alive budget.
                    continue;
                }
                idle_ms += TICK_MS;
                if idle_ms >= keep_alive_ms {
                    break;
                }
            }
            Ok(Outcome::Closed) => break,
            Ok(Outcome::Message(msg)) => {
                idle_ms = 0;
                metrics.http_requests.fetch_add(1, Ordering::Relaxed);
                let (resp, client_close) = match Request::from_message(msg) {
                    Ok(req) => {
                        let close = req.wants_close();
                        (routes::route(&svc, &metrics, &req), close)
                    }
                    Err(e) => (Response::error(400, &format!("{e:#}")), true),
                };
                metrics.count_status(resp.status);
                let closing = client_close || shutdown.load(Ordering::SeqCst);
                if resp.write_to(&mut conn, closing).is_err() || closing {
                    break;
                }
            }
            Err(e) => {
                // Malformed request: best-effort 400, then drop.  It
                // still counts as a request so responses never
                // outnumber requests in /metrics.
                metrics.http_requests.fetch_add(1, Ordering::Relaxed);
                metrics.count_status(400);
                let _ = Response::error(400, &format!("{e:#}")).write_to(&mut conn, true);
                break;
            }
        }
    }
}
