//! `printed_bespoke` — a bespoke-microprocessor design framework for
//! printed electronics, reproducing *"A Bespoke Design Approach to
//! Low-Power Printed Microprocessors for Machine Learning Applications"*
//! (Chaidos et al., 2025).
//!
//! The library is the L3 layer of a three-layer stack:
//!
//! * **L1** (build-time Python): the paper's SIMD MAC unit as a bit-exact
//!   Pallas kernel (`python/compile/kernels/simd_mac.py`).
//! * **L2** (build-time Python): the six evaluation models (3 MLPs,
//!   3 SVMs) in JAX, AOT-lowered to HLO text under `artifacts/`.
//! * **L3** (this crate): the bespoke design workflow — printed-technology
//!   cost modelling ([`hw`]), ISA toolchains ([`isa`]), cycle-approximate
//!   simulators ([`sim`]), ML code generation ([`ml`]), utilization-driven
//!   logic reduction ([`bespoke`]), design-space exploration ([`dse`]),
//!   and a PJRT-backed evaluation service ([`runtime`], [`coordinator`])
//!   fronted by an owned HTTP serving layer ([`server`]).
//!
//! Python never runs on the request path: after `make artifacts`, the
//! rust binary is self-contained.

pub mod bespoke;
pub mod coordinator;
pub mod dse;
pub mod hw;
pub mod isa;
pub mod ml;
pub mod runtime;
pub mod server;
pub mod sim;
pub mod util;

/// Locate the artifact tree, in priority order:
///
/// 1. `$PBSP_ARTIFACTS` — an explicit override always wins;
/// 2. a real `artifacts/` directory (the `make artifacts` AOT output),
///    found by walking up from the current directory;
/// 3. the checked-in hermetic fixture tree `artifacts-fixture/`
///    ([`ml::fixtures`]), so `cargo test` passes on a fresh checkout
///    with no Python setup at all.
pub fn artifacts_dir() -> anyhow::Result<std::path::PathBuf> {
    let env = std::env::var_os("PBSP_ARTIFACTS").map(std::path::PathBuf::from);
    resolve_artifacts_dir(env, std::env::current_dir()?)
}

/// Deterministic core of [`artifacts_dir`], split out so override
/// precedence is testable without mutating the process environment.
pub(crate) fn resolve_artifacts_dir(
    env_override: Option<std::path::PathBuf>,
    start: std::path::PathBuf,
) -> anyhow::Result<std::path::PathBuf> {
    if let Some(p) = env_override {
        return Ok(p);
    }
    if let Some(real) = ml::fixtures::find_up_from(start.clone(), "artifacts") {
        return Ok(real);
    }
    if let Some(fixture) = ml::fixtures::find_up_from(start, ml::fixtures::FIXTURE_DIR_NAME) {
        return Ok(fixture);
    }
    anyhow::bail!(
        "no artifacts found: run `make artifacts` (full AOT output), set \
         PBSP_ARTIFACTS, or restore the checked-in artifacts-fixture/ \
         fallback (regenerate with `python3 tools/gen_fixture.py`)"
    )
}
