//! `printed_bespoke` — a bespoke-microprocessor design framework for
//! printed electronics, reproducing *"A Bespoke Design Approach to
//! Low-Power Printed Microprocessors for Machine Learning Applications"*
//! (Chaidos et al., 2025).
//!
//! The library is the L3 layer of a three-layer stack:
//!
//! * **L1** (build-time Python): the paper's SIMD MAC unit as a bit-exact
//!   Pallas kernel (`python/compile/kernels/simd_mac.py`).
//! * **L2** (build-time Python): the six evaluation models (3 MLPs,
//!   3 SVMs) in JAX, AOT-lowered to HLO text under `artifacts/`.
//! * **L3** (this crate): the bespoke design workflow — printed-technology
//!   cost modelling ([`hw`]), ISA toolchains ([`isa`]), cycle-approximate
//!   simulators ([`sim`]), ML code generation ([`ml`]), utilization-driven
//!   logic reduction ([`bespoke`]), design-space exploration ([`dse`]),
//!   and a PJRT-backed evaluation service ([`runtime`], [`coordinator`]).
//!
//! Python never runs on the request path: after `make artifacts`, the
//! rust binary is self-contained.

pub mod bespoke;
pub mod coordinator;
pub mod dse;
pub mod hw;
pub mod isa;
pub mod ml;
pub mod runtime;
pub mod sim;
pub mod util;

/// Locate the repository's `artifacts/` directory: `$PBSP_ARTIFACTS`, or
/// walk up from the current directory until one is found.
pub fn artifacts_dir() -> anyhow::Result<std::path::PathBuf> {
    if let Ok(p) = std::env::var("PBSP_ARTIFACTS") {
        return Ok(std::path::PathBuf::from(p));
    }
    let mut dir = std::env::current_dir()?;
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.json").is_file() {
            return Ok(cand);
        }
        if !dir.pop() {
            anyhow::bail!(
                "artifacts/manifest.json not found; run `make artifacts` \
                 or set PBSP_ARTIFACTS"
            );
        }
    }
}
