//! The §III-A profiling suite: "a 3-layer Multi-Layer Perceptron (MLP),
//! a depth-2 Decision Tree (DT), simple Multiplication-Division and
//! Insertion Sort on array of size 16".
//!
//! These are the workloads whose execution profiles drive the bespoke
//! reduction pass (which instructions / registers / CSRs / PC range a
//! deployment actually uses).  Each returns an assembled RV32 program.

use anyhow::Result;

use crate::isa::rv32::Instr;
use crate::isa::rv32_asm::Asm;
use crate::sim::mem::RAM_BASE;

/// A tiny fixed 3-layer MLP (4-4-4-2) on synthetic fixed inputs —
/// pure-ALU inference in the style of the ML codegen, for profiling.
pub fn mlp_3layer() -> Result<Vec<Instr>> {
    let mut a = Asm::new();
    a.li(18, RAM_BASE as i32); // s2 RAM base
    // Write a fixed input vector (4 x i16) to RAM.
    for (i, v) in [300i32, -200, 150, 50].iter().enumerate() {
        a.li(5, *v);
        a.push(Instr::Store {
            op: crate::isa::rv32::StoreOp::Sh,
            rs2: 5,
            rs1: 18,
            offset: 0x40 + 2 * i as i32,
        });
    }
    // Three dense layers with pseudo-random constant weights (li'd
    // inline): out[j] = relu(sum_k in[k] * w) — weights derived from a
    // tiny LCG at build time for determinism.
    let mut seed = 0x1234u32;
    let mut next_w = move || {
        seed = seed.wrapping_mul(1103515245).wrapping_add(12345);
        ((seed >> 16) as i32 % 64) - 32
    };
    let widths = [4usize, 4, 4, 2];
    let mut in_off = 0x40;
    let mut out_off = 0x80;
    for l in 0..3 {
        let (k, n) = (widths[l], widths[l + 1]);
        for j in 0..n {
            a.li(10, 0); // acc
            for kk in 0..k {
                a.push(Instr::Load {
                    op: crate::isa::rv32::LoadOp::Lh,
                    rd: 5,
                    rs1: 18,
                    offset: in_off + 2 * kk as i32,
                });
                a.li(6, next_w());
                a.mul(7, 5, 6);
                a.add(10, 10, 7);
            }
            a.srai(10, 10, 5);
            // ReLU.
            let tag = format!("mb_relu_{l}_{j}");
            a.bge(10, 0, &tag);
            a.li(10, 0);
            a.label(&tag);
            a.push(Instr::Store {
                op: crate::isa::rv32::StoreOp::Sh,
                rs2: 10,
                rs1: 18,
                offset: out_off + 2 * j as i32,
            });
        }
        std::mem::swap(&mut in_off, &mut out_off);
    }
    a.ebreak();
    a.finish()
}

/// Depth-2 decision tree over 3 fixed features.
pub fn decision_tree() -> Result<Vec<Instr>> {
    let mut a = Asm::new();
    a.li(18, RAM_BASE as i32);
    a.li(5, 37); // f0
    a.li(6, -12); // f1
    a.li(7, 99); // f2
    a.li(28, 50); // threshold t0
    a.blt(5, 28, "left");
    // Right subtree: f2 < 80 ?
    a.li(28, 80);
    a.blt(7, 28, "leaf2");
    a.li(10, 3);
    a.j("done");
    a.label("leaf2");
    a.li(10, 2);
    a.j("done");
    a.label("left");
    // Left subtree: f1 < 0 ?
    a.bge(6, 0, "leaf1");
    a.li(10, 0);
    a.j("done");
    a.label("leaf1");
    a.li(10, 1);
    a.label("done");
    a.sw(10, 18, 0);
    a.ebreak();
    a.finish()
}

/// Multiplication/division microkernel (exercises MUL/DIV/REM).
pub fn mul_div() -> Result<Vec<Instr>> {
    let mut a = Asm::new();
    a.li(18, RAM_BASE as i32);
    a.li(5, 12345);
    a.li(6, 67);
    a.mul(10, 5, 6);
    a.push(Instr::MulDiv { op: crate::isa::rv32::MulOp::Div, rd: 11, rs1: 10, rs2: 6 });
    a.push(Instr::MulDiv { op: crate::isa::rv32::MulOp::Rem, rd: 12, rs1: 10, rs2: 5 });
    a.sw(10, 18, 0);
    a.sw(11, 18, 4);
    a.sw(12, 18, 8);
    a.ebreak();
    a.finish()
}

/// Insertion sort of a 16-element array in RAM (paper: "Insertion Sort
/// on array of size 16").
pub fn insertion_sort() -> Result<Vec<Instr>> {
    let mut a = Asm::new();
    a.li(18, RAM_BASE as i32);
    // Seed the array with a deterministic LCG.
    a.li(5, 0x5eed);
    a.li(6, 0); // i
    a.li(7, 16);
    a.label("fill");
    a.li(28, 1103515245u32 as i32);
    a.mul(5, 5, 28);
    a.addi(5, 5, 12345 & 0x7ff);
    a.push(Instr::OpImm { op: crate::isa::rv32::AluOp::Sra, rd: 29, rs1: 5, imm: 16 });
    a.slli(30, 6, 2);
    a.add(30, 30, 18);
    a.sw(29, 30, 0);
    a.addi(6, 6, 1);
    a.blt(6, 7, "fill");
    // Insertion sort.
    a.li(6, 1); // i = 1
    a.label("outer");
    a.slli(30, 6, 2);
    a.add(30, 30, 18);
    a.lw(28, 30, 0); // key
    a.mv(29, 6); // j = i
    a.label("inner");
    a.beq(29, 0, "insert");
    a.slli(30, 29, 2);
    a.add(30, 30, 18);
    a.lw(31, 30, -4); // a[j-1]
    a.blt(28, 31, "shift");
    a.j("insert");
    a.label("shift");
    a.sw(31, 30, 0);
    a.addi(29, 29, -1);
    a.j("inner");
    a.label("insert");
    a.slli(30, 29, 2);
    a.add(30, 30, 18);
    a.sw(28, 30, 0);
    a.addi(6, 6, 1);
    a.blt(6, 7, "outer");
    a.ebreak();
    a.finish()
}

/// The whole profiling suite, named.
pub fn suite() -> Result<Vec<(&'static str, Vec<Instr>)>> {
    Ok(vec![
        ("mlp3", mlp_3layer()?),
        ("dtree", decision_tree()?),
        ("muldiv", mul_div()?),
        ("isort", insertion_sort()?),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::zero_riscy::{Halt, ZeroRiscy};

    fn run(prog: Vec<Instr>) -> ZeroRiscy {
        let mut sim = ZeroRiscy::new(&prog, &[], 0x400, None);
        assert_eq!(sim.run(10_000_000).unwrap(), Halt::Break);
        sim
    }

    #[test]
    fn suite_runs_clean() {
        for (name, prog) in suite().unwrap() {
            let sim = run(prog);
            assert!(sim.profile.cycles > 0, "{name}");
        }
    }

    #[test]
    fn insertion_sort_sorts() {
        let sim = run(insertion_sort().unwrap());
        let mut vals = Vec::new();
        for i in 0..16 {
            vals.push(sim.mem.load_u32(crate::sim::mem::RAM_BASE + 4 * i).unwrap() as i32);
        }
        let mut sorted = vals.clone();
        sorted.sort();
        assert_eq!(vals, sorted);
    }

    #[test]
    fn muldiv_values() {
        let sim = run(mul_div().unwrap());
        assert_eq!(sim.regs[10], 12345 * 67);
        assert_eq!(sim.regs[11], 12345);
        assert_eq!(sim.regs[12], (12345 * 67) % 12345);
    }

    #[test]
    fn decision_tree_classifies() {
        let sim = run(decision_tree().unwrap());
        // f0=37 < 50 -> left; f1=-12 < 0 -> class 0.
        assert_eq!(sim.regs[10], 0);
    }

    #[test]
    fn suite_profile_shows_unused_instrs() {
        // The paper's observation: SLT, CSR ops, syscalls, MULH remain
        // unused across the suite.
        let mut merged = crate::sim::trace::Profile::default();
        for (_, prog) in suite().unwrap() {
            let sim = run(prog);
            merged.merge(&sim.profile);
        }
        let unused = merged.unused_mnemonics(crate::sim::zero_riscy::ALL_MNEMONICS);
        for m in ["slt", "slti", "csrrw", "csrrs", "csrrc", "ecall", "mulh", "mulhu"] {
            assert!(unused.contains(&m), "{m} should be unused");
        }
        assert!(!unused.contains(&"mul"));
        assert!(!merged.csr_used);
    }
}
