//! Dataset loading from the CSV artifacts written by
//! `python/compile/datasets.py`.

use anyhow::Result;

use crate::util::csv::Table;

#[derive(Debug, Clone)]
pub struct Dataset {
    pub name: String,
    pub x: Vec<Vec<f32>>,
    pub y: Vec<i64>,
}

impl Dataset {
    pub fn load(dir: impl AsRef<std::path::Path>, name: &str, split: &str) -> Result<Dataset> {
        let path = dir.as_ref().join(format!("{name}_{split}.csv"));
        let (x, y) = Table::from_file(path)?.features_labels()?;
        Ok(Dataset { name: name.to_string(), x, y })
    }

    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    pub fn n_features(&self) -> usize {
        self.x.first().map(Vec::len).unwrap_or(0)
    }

    /// Accuracy of a prediction vector against the labels.
    pub fn accuracy(&self, preds: &[i64]) -> f64 {
        assert_eq!(preds.len(), self.y.len());
        let hits = preds.iter().zip(&self.y).filter(|(p, y)| p == y).count();
        hits as f64 / self.y.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_from_tempdir() {
        let dir = std::env::temp_dir().join(format!("pbsp-ds-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("toy_test.csv"),
            "f0,f1,label\n0.1,0.9,1\n0.8,0.2,0\n0.5,0.5,1\n",
        )
        .unwrap();
        let ds = Dataset::load(&dir, "toy", "test").unwrap();
        assert_eq!(ds.len(), 3);
        assert_eq!(ds.n_features(), 2);
        assert_eq!(ds.y, vec![1, 0, 1]);
        assert!((ds.accuracy(&[1, 0, 0]) - 2.0 / 3.0).abs() < 1e-12);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
