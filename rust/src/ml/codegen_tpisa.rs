//! TP-ISA code generation: lower a quantised model to the minimal
//! printed core, in two variants (paper Fig. 5 / Table II):
//!
//! * [`TpVariant::Baseline`] — no hardware multiply: every product is a
//!   signed shift-add *software multiply* on the ALU ("the whole
//!   operation is scheduled to the ALU", §III-B), with the 32-bit
//!   accumulator held in data memory as `32/d` words and carried through
//!   ADC chains.
//! * [`TpVariant::Mac { precision }`] — the SIMD MAC unit: packed
//!   `ld/ld/mac` with d/p lanes, accumulators read back in d-bit chunks.
//!
//! Addressing strategy:
//!
//! * d >= 8 — looped inner products with pointer registers (r7 = x,
//!   r6 = w) and a memory-resident k-counter.
//! * d = 4 — registers cannot hold addresses, so programs are fully
//!   unrolled with immediate-only addressing off a zeroed base register;
//!   the whole data image must fit 64 words, which holds for the
//!   single-layer SVM models (the paper's 4-bit TP-ISA similarly
//!   targets the smallest configurations, §IV-A).
//!
//! Data-memory layout (word-addressed, d-bit cells):
//!
//! ```text
//! 0                  k-loop counter scratch
//! 1 .. 1+nacc        accumulator scratch (nacc = 32/d words)
//! score_base ..      n_scores x nacc accumulator words (output)
//! input_base ..      input vector (1 word/value, or packed for MAC)
//! hidden_base ..     hidden activations (1 word/value)
//! packed_base ..     packed hidden words (MAC with >1 lane only)
//! const_base ..      weights, biases, rounding constants
//! ```

use std::sync::Arc;

use anyhow::{ensure, Result};

use super::model::{Model, QLayer};
use super::quant::{pack_vec, qlimits};
use crate::hw::mac_unit::MacConfig;
use crate::isa::tpisa::{Asm, Instr};
use crate::isa::MacOp;
use crate::sim::prepared::PreparedTpIsa;

/// Program variant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TpVariant {
    Baseline,
    Mac { precision: u32 },
}

impl TpVariant {
    pub fn label(&self) -> String {
        match self {
            TpVariant::Baseline => "baseline".into(),
            TpVariant::Mac { precision } => format!("mac-p{precision}"),
        }
    }

    /// The MAC unit a `datapath`-bit core running this variant carries.
    pub fn mac_config(&self, datapath: u32) -> Option<MacConfig> {
        match self {
            TpVariant::Baseline => None,
            TpVariant::Mac { precision } => Some(MacConfig::new(datapath, *precision)),
        }
    }
}

/// A generated TP-ISA program plus its I/O contract.
#[derive(Debug, Clone)]
pub struct TpIsaProgram {
    pub code: Vec<Instr>,
    /// Initial data-memory image (constants; input region zeroed).
    pub dmem_image: Vec<u64>,
    /// Shared prepared image (code + masked initial dmem + MAC config)
    /// — built once here so the harness constructs simulators with a
    /// memcpy instead of per-word constant stores.
    pub prepared: Arc<PreparedTpIsa>,
    pub datapath: u32,
    pub variant: TpVariant,
    pub quant_precision: u32,
    pub packed_input: bool,
    pub input_base: usize,
    pub score_base: usize,
    pub n_scores: usize,
    pub score_scale: f64,
    pub dmem_words: usize,
    /// ROM cells (bytes): 2 per instruction + constant-data bytes.
    pub rom_cells: usize,
}

impl TpIsaProgram {
    pub fn mac_config(&self) -> Option<MacConfig> {
        self.variant.mac_config(self.datapath)
    }

    /// Block-cache statistics of the pre-translated image — the
    /// generated idioms (the soft-multiply shift-add kernel, the
    /// `ld/ld/mac` bodies, the `ld/<alu>/st` accumulator updates) sit
    /// on known instruction boundaries, so the translator's peephole
    /// pass must fuse them; `perf_iss` reports these numbers per model.
    pub fn translate_stats(&self) -> &crate::sim::translate::TranslateStats {
        &self.prepared.translated.stats
    }
}

/// Quantisation precision a variant runs at (baseline: the datapath
/// width capped at 16 — "all the models' parameters are 16-bits").
pub fn quant_precision(datapath: u32, variant: TpVariant) -> u32 {
    match variant {
        TpVariant::Baseline => datapath.min(16),
        TpVariant::Mac { precision } => precision,
    }
}

// Register conventions (d >= 8 looped mode):
//   r0, r1  softmul x_lo/x_hi; general temps
//   r2      softmul w / zero-base for imm-only access
//   r3, r4  softmul product lo/hi; MAC readback temps
//   r5      softmul counter / sign-fill / shift counter
//   r6      w pointer (also const pointer in epilogues)
//   r7      x pointer (also score/hidden pointer in epilogues)
const KCNT: usize = 0;
const ACC: usize = 1;

struct Layout {
    nacc: usize,
    score_base: usize,
    input_base: usize,
    hidden_base: usize,
    packed_base: usize,
    const_base: usize,
}

/// Generate a TP-ISA program for `model` on a `datapath`-bit core.
pub fn generate(model: &Model, datapath: u32, variant: TpVariant) -> Result<TpIsaProgram> {
    ensure!(matches!(datapath, 4 | 8 | 16 | 32), "TP-ISA widths: 4/8/16/32");
    if let TpVariant::Mac { precision } = variant {
        ensure!(precision <= datapath, "MAC precision wider than datapath");
    }
    let p = quant_precision(datapath, variant);
    let qls: &[QLayer] = model.qlayers(p)?;
    let d = datapath;
    let nacc = (32 / d).max(1) as usize;
    let lanes = match variant {
        TpVariant::Baseline => 1,
        TpVariant::Mac { precision } => (d / precision).max(1) as usize,
    };
    let packed_input = matches!(variant, TpVariant::Mac { .. });

    let k0 = model.arch[0];
    let in_words = if packed_input { k0.div_ceil(lanes) } else { k0 };
    let max_hidden = model.arch[1..model.arch.len() - 1].iter().copied().max().unwrap_or(0);
    let n_scores = model.raw_outputs();

    let score_base = ACC + nacc;
    let input_base = score_base + n_scores * nacc;
    let hidden_base = input_base + in_words;
    let packed_base = hidden_base + max_hidden;
    let const_base = packed_base + if lanes > 1 { max_hidden.div_ceil(lanes) } else { 0 };
    let lay = Layout { nacc, score_base, input_base, hidden_base, packed_base, const_base };

    let mut consts: Vec<u64> = Vec::new();
    let mut a = Asm::new();

    let unrolled = d == 4;
    if unrolled {
        ensure!(
            model.layers.len() == 1,
            "4-bit TP-ISA supports single-layer models (immediate-only addressing)"
        );
        a.ldi(6, 0); // r6 = zero base for imm-only addressing
    }

    let last_idx = model.layers.len() - 1;
    let mut layer_in = lay.input_base;
    for (li, (layer, ql)) in model.layers.iter().zip(qls).enumerate() {
        let k = ql.qw.len();
        let n = ql.qb.len();
        let last = li == last_idx;

        // Constant data for this layer: per-output weight columns,
        // bias as nacc acc-words, rounding constant as nacc words.
        let mask = if d == 64 { u64::MAX } else { (1u64 << d) - 1 };
        let acc_words = |v: i64| -> Vec<u64> {
            (0..nacc).map(|w| ((v as u64) >> (d * w as u32)) & mask).collect()
        };
        let col_addr: Vec<usize> = (0..n)
            .map(|j| {
                let addr = lay.const_base + consts.len();
                let col: Vec<i64> = (0..k).map(|kk| ql.qw[kk][j]).collect();
                if packed_input {
                    let prec = p;
                    consts.extend(pack_vec(&col, prec, d));
                } else {
                    consts.extend(col.iter().map(|&v| (v as u64) & mask));
                }
                addr
            })
            .collect();
        let bias_addr: Vec<usize> = (0..n)
            .map(|j| {
                let addr = lay.const_base + consts.len();
                consts.extend(acc_words(ql.qb[j]));
                addr
            })
            .collect();
        let round_addr = {
            let addr = lay.const_base + consts.len();
            let rc = if ql.shift > 0 { 1i64 << (ql.shift - 1) } else { 0 };
            consts.extend(acc_words(rc));
            addr
        };

        let in_words_l =
            if packed_input { k.div_ceil(lanes) } else { k };

        for j in 0..n {
            let tag = format!("l{li}o{j}");
            if unrolled {
                emit_output_unrolled(
                    &mut a, &tag, model, ql, variant, d, p, k, j, layer_in, col_addr[j],
                    bias_addr[j], &lay, last,
                )?;
            } else {
                emit_output_looped(
                    &mut a, &tag, ql, variant, d, p, k, in_words_l, j, layer_in, col_addr[j],
                    bias_addr[j], round_addr, &lay, last, layer.relu, lanes,
                )?;
            }
        }
        // Pack hidden values for the next MAC layer if lanes > 1.
        if !last && lanes > 1 {
            emit_pack_hidden(&mut a, d, p, model.arch[li + 1 - 0], &lay)?;
            layer_in = lay.packed_base;
        } else {
            layer_in = lay.hidden_base;
        }
    }
    a.push(Instr::Halt);

    let code = a.finish()?;
    let dmem_words = lay.const_base + consts.len() + 4;
    if unrolled {
        ensure!(dmem_words <= 64, "4-bit TP-ISA data image exceeds 64 words ({dmem_words})");
    }
    let mut dmem_image = vec![0u64; dmem_words];
    for (i, &c) in consts.iter().enumerate() {
        dmem_image[lay.const_base + i] = c;
    }

    let lastq = &qls[last_idx];
    let const_bytes = (consts.len() * d as usize).div_ceil(8);
    let prepared =
        Arc::new(PreparedTpIsa::new(d, &code, dmem_image.clone(), variant.mac_config(d)));
    Ok(TpIsaProgram {
        rom_cells: code.len() * 2 + const_bytes,
        code,
        dmem_image,
        prepared,
        datapath: d,
        variant,
        quant_precision: p,
        packed_input,
        input_base: lay.input_base,
        score_base: lay.score_base,
        n_scores,
        score_scale: (1i64 << (lastq.fx + lastq.fw)) as f64,
        dmem_words,
    })
}

/// Looped per-output inner product (d >= 8).
#[allow(clippy::too_many_arguments)]
fn emit_output_looped(
    a: &mut Asm,
    tag: &str,
    ql: &QLayer,
    variant: TpVariant,
    d: u32,
    p: u32,
    _k: usize,
    in_words: usize,
    _j: usize,
    in_base: usize,
    col_addr: usize,
    bias_addr: usize,
    round_addr: usize,
    lay: &Layout,
    last: bool,
    relu: bool,
    lanes: usize,
) -> Result<()> {
    let nacc = lay.nacc;
    if matches!(variant, TpVariant::Mac { .. }) {
        a.push(Instr::Mac { op: MacOp::MacClr, r1: 0, r2: 0 });
    }
    // acc = bias.
    a.ldc(7, bias_addr as i64, d);
    a.ldi(2, 0);
    for w in 0..nacc {
        a.push(Instr::Ld { r1: 0, r2: 7, imm: w as i8 });
        a.push(Instr::St { r1: 0, r2: 2, imm: (ACC + w) as i8 });
    }
    // kcnt = in_words.
    a.ldc(0, in_words as i64, d);
    a.push(Instr::St { r1: 0, r2: 2, imm: KCNT as i8 });
    // Pointers.
    a.ldc(7, in_base as i64, d);
    a.ldc(6, col_addr as i64, d);

    a.label(&format!("kloop_{tag}"));
    match variant {
        TpVariant::Baseline => {
            // x -> (r0, r1), w -> r2, softmul -> (r3, r4).
            a.push(Instr::Ld { r1: 0, r2: 7, imm: 0 });
            a.push(Instr::Sxt { r1: 1, r2: 0 });
            a.push(Instr::Ld { r1: 2, r2: 6, imm: 0 });
            emit_softmul(a, tag, d, p);
            // acc += sign-extended product.
            let np = if 2 * p <= d { 1 } else { 2 };
            if np == 1 {
                a.push(Instr::Sxt { r1: 5, r2: 3 });
            } else {
                a.push(Instr::Sxt { r1: 5, r2: 4 });
            }
            a.ldi(2, 0);
            a.push(Instr::Ld { r1: 0, r2: 2, imm: ACC as i8 });
            a.push(Instr::Add { r1: 0, r2: 3 });
            a.push(Instr::St { r1: 0, r2: 2, imm: ACC as i8 });
            for w in 1..nacc {
                a.push(Instr::Ld { r1: 0, r2: 2, imm: (ACC + w) as i8 });
                let src = if w < np { 4 } else { 5 };
                a.push(Instr::Adc { r1: 0, r2: src });
                a.push(Instr::St { r1: 0, r2: 2, imm: (ACC + w) as i8 });
            }
        }
        TpVariant::Mac { .. } => {
            // r2 stays 0 across the loop (nothing clobbers it here).
            a.push(Instr::Ld { r1: 0, r2: 7, imm: 0 });
            a.push(Instr::Ld { r1: 1, r2: 6, imm: 0 });
            a.push(Instr::Mac { op: MacOp::Mac, r1: 0, r2: 1 });
        }
    }
    // Advance pointers + counter.
    a.push(Instr::Addi { r1: 7, imm: 1 });
    a.push(Instr::Addi { r1: 6, imm: 1 });
    a.push(Instr::Ld { r1: 0, r2: 2, imm: KCNT as i8 });
    a.push(Instr::Addi { r1: 0, imm: -1 });
    a.push(Instr::St { r1: 0, r2: 2, imm: KCNT as i8 });
    a.bnz(&format!("kloop_{tag}"));

    if let TpVariant::Mac { .. } = variant {
        // Read the adder-tree total `acc_total` in d-bit chunks and add
        // it onto the bias-seeded memory accumulator (paper Eq. 1: the
        // unit sums lanes in hardware, Fig. 2).
        let _ = lanes;
        let parts = (32u32.div_ceil(d)) as usize;
        a.ldi(2, 0);
        for part in 0..parts {
            a.push(Instr::Mac { op: MacOp::MacRd, r1: 3, r2: part as u8 });
            a.push(Instr::Ld { r1: 0, r2: 2, imm: (ACC + part) as i8 });
            if part == 0 {
                a.push(Instr::Add { r1: 0, r2: 3 });
            } else {
                a.push(Instr::Adc { r1: 0, r2: 3 });
            }
            a.push(Instr::St { r1: 0, r2: 2, imm: (ACC + part) as i8 });
        }
    }
    emit_epilogue(a, tag, ql, d, p, _j, lay, last, relu, round_addr)
}

/// Signed shift-add multiply: x in (r0 lo, r1 hi), w in r2; product
/// left in (r3, r4).  p-1 conditional adds then a conditional subtract
/// for the sign bit (two's complement).  Clobbers r5.
fn emit_softmul(a: &mut Asm, tag: &str, d: u32, p: u32) {
    let np2 = 2 * p > d; // product needs two words
    a.ldi(3, 0);
    if np2 {
        a.ldi(4, 0);
    }
    a.ldi(5, (p - 1) as i8);
    a.label(&format!("smul_{tag}"));
    a.push(Instr::Shr { r1: 2 }); // carry = multiplier LSB
    a.bnc(&format!("smul_skip_{tag}"));
    a.push(Instr::Add { r1: 3, r2: 0 });
    if np2 {
        a.push(Instr::Adc { r1: 4, r2: 1 });
    }
    a.label(&format!("smul_skip_{tag}"));
    a.push(Instr::Shl { r1: 0 });
    if np2 {
        a.push(Instr::Slc { r1: 1 });
    }
    a.push(Instr::Addi { r1: 5, imm: -1 });
    a.bnz(&format!("smul_{tag}"));
    // Sign bit: subtract x << (p-1).
    a.push(Instr::Shr { r1: 2 });
    a.bnc(&format!("smul_done_{tag}"));
    a.push(Instr::Sub { r1: 3, r2: 0 });
    if np2 {
        a.push(Instr::Sbc { r1: 4, r2: 1 });
    }
    a.label(&format!("smul_done_{tag}"));
}

/// Rescale + saturate + ReLU + store (hidden) or copy acc to the score
/// region (last layer).
#[allow(clippy::too_many_arguments)]
fn emit_epilogue(
    a: &mut Asm,
    tag: &str,
    ql: &QLayer,
    d: u32,
    p: u32,
    j: usize,
    lay: &Layout,
    last: bool,
    relu: bool,
    round_addr: usize,
) -> Result<()> {
    let nacc = lay.nacc;
    a.ldi(2, 0);
    if last {
        // Copy acc words to the score slot.
        a.ldc(7, (lay.score_base + j * nacc) as i64, d);
        for w in 0..nacc {
            a.push(Instr::Ld { r1: 0, r2: 2, imm: (ACC + w) as i8 });
            a.push(Instr::St { r1: 0, r2: 7, imm: w as i8 });
        }
        return Ok(());
    }
    // (a) acc += rounding constant.
    if ql.shift > 0 {
        a.ldc(6, round_addr as i64, d);
        for w in 0..nacc {
            a.push(Instr::Ld { r1: 0, r2: 2, imm: (ACC + w) as i8 });
            a.push(Instr::Ld { r1: 1, r2: 6, imm: w as i8 });
            if w == 0 {
                a.push(Instr::Add { r1: 0, r2: 1 });
            } else {
                a.push(Instr::Adc { r1: 0, r2: 1 });
            }
            a.push(Instr::St { r1: 0, r2: 2, imm: (ACC + w) as i8 });
        }
        // (b) arithmetic shift right `shift` times across nacc words.
        a.ldi(5, ql.shift as i8);
        a.label(&format!("shl_{tag}"));
        a.push(Instr::Ld { r1: 0, r2: 2, imm: (ACC + nacc - 1) as i8 });
        a.push(Instr::Sra { r1: 0 });
        a.push(Instr::St { r1: 0, r2: 2, imm: (ACC + nacc - 1) as i8 });
        for w in (0..nacc - 1).rev() {
            a.push(Instr::Ld { r1: 0, r2: 2, imm: (ACC + w) as i8 });
            a.push(Instr::Src { r1: 0 });
            a.push(Instr::St { r1: 0, r2: 2, imm: (ACC + w) as i8 });
        }
        a.push(Instr::Addi { r1: 5, imm: -1 });
        a.bnz(&format!("shl_{tag}"));
    }
    // (c) saturate to p bits.  v = acc low word; if the upper words are
    // not the sign-fill of v, clamp to qmin/qmax by the sign of the
    // top word.  (For p == d the in-range value is exactly the low
    // word; for p < d also check the low word fits p bits.)
    a.push(Instr::Ld { r1: 0, r2: 2, imm: ACC as i8 });
    a.push(Instr::Sxt { r1: 1, r2: 0 });
    for w in 1..nacc {
        a.push(Instr::Ld { r1: 3, r2: 2, imm: (ACC + w) as i8 });
        a.push(Instr::Xor { r1: 3, r2: 1 });
        a.bnz(&format!("clamp_{tag}"));
    }
    if p < d {
        // In-word range check against the p-bit bounds.  `Sub` sets Z
        // on equality; the sign fill of the difference distinguishes
        // below/above.  (Wrap-around at the word width only occurs for
        // |v| far outside the p-bit range, where the clamp branch picks
        // the correct bound from the top acc word's sign.)
        let (qmin, qmax) = qlimits(p);
        a.push(Instr::Mov { r1: 3, r2: 0 });
        a.ldc(4, qmax, d);
        a.push(Instr::Sub { r1: 3, r2: 4 }); // v - qmax; Z if equal
        a.bz(&format!("satok_{tag}"));
        a.push(Instr::Sxt { r1: 5, r2: 3 });
        a.push(Instr::Or { r1: 5, r2: 5 }); // Z iff difference >= 0
        a.bnz(&format!("satlo_{tag}")); // negative -> v < qmax: check min
        a.jmp(&format!("clamp_{tag}")); // v > qmax
        a.label(&format!("satlo_{tag}"));
        a.push(Instr::Mov { r1: 3, r2: 0 });
        a.ldc(4, qmin, d);
        a.push(Instr::Sub { r1: 3, r2: 4 }); // v - qmin; Z if equal
        a.bz(&format!("satok_{tag}"));
        a.push(Instr::Sxt { r1: 5, r2: 3 });
        a.push(Instr::Or { r1: 5, r2: 5 });
        a.bnz(&format!("clamp_{tag}")); // negative -> v < qmin
        a.label(&format!("satok_{tag}"));
    }
    a.jmp(&format!("store_{tag}"));
    a.label(&format!("clamp_{tag}"));
    // Sign from the top acc word.
    a.push(Instr::Ld { r1: 3, r2: 2, imm: (ACC + nacc - 1) as i8 });
    a.push(Instr::Sxt { r1: 4, r2: 3 });
    a.push(Instr::Or { r1: 4, r2: 4 });
    a.bz(&format!("clamp_pos_{tag}"));
    a.ldc(0, qlimits(p).0, d);
    a.jmp(&format!("store_{tag}"));
    a.label(&format!("clamp_pos_{tag}"));
    a.ldc(0, qlimits(p).1, d);
    a.label(&format!("store_{tag}"));
    if relu {
        a.push(Instr::Sxt { r1: 1, r2: 0 });
        a.push(Instr::Or { r1: 1, r2: 1 });
        a.bz(&format!("relu_{tag}"));
        a.ldi(0, 0);
        a.label(&format!("relu_{tag}"));
    }
    a.ldc(7, (lay.hidden_base + j) as i64, d);
    a.push(Instr::St { r1: 0, r2: 7, imm: 0 });
    Ok(())
}

/// Pack hidden single-word values into lane-packed words for the next
/// MAC layer.
fn emit_pack_hidden(a: &mut Asm, d: u32, p: u32, k_next: usize, lay: &Layout) -> Result<()> {
    let lanes = (d / p).max(1) as usize;
    let words = k_next.div_ceil(lanes);
    a.ldc(1, (1i64 << p) - 1, d); // lane mask
    for w in 0..words {
        a.ldi(3, 0);
        for lane in (0..lanes).rev() {
            let idx = w * lanes + lane;
            if lane != lanes - 1 {
                // Shift the accumulated word left by one lane.
                for _ in 0..p {
                    a.push(Instr::Shl { r1: 3 });
                }
            }
            if idx < k_next {
                a.ldc(7, (lay.hidden_base + idx) as i64, d);
                a.push(Instr::Ld { r1: 0, r2: 7, imm: 0 });
                a.push(Instr::And { r1: 0, r2: 1 });
                a.push(Instr::Or { r1: 3, r2: 0 });
            }
        }
        a.ldc(7, (lay.packed_base + w) as i64, d);
        a.push(Instr::St { r1: 3, r2: 7, imm: 0 });
    }
    Ok(())
}

/// Fully unrolled single-layer output for the 4-bit core (immediate-only
/// addressing; r6 holds 0).
#[allow(clippy::too_many_arguments)]
fn emit_output_unrolled(
    a: &mut Asm,
    tag: &str,
    _model: &Model,
    _ql: &QLayer,
    variant: TpVariant,
    d: u32,
    p: u32,
    k: usize,
    j: usize,
    in_base: usize,
    col_addr: usize,
    bias_addr: usize,
    lay: &Layout,
    _last: bool,
) -> Result<()> {
    let nacc = lay.nacc;
    ensure!(col_addr + k <= 64 && bias_addr + nacc <= 64, "data beyond imm range");
    // acc = bias.
    for w in 0..nacc {
        a.push(Instr::Ld { r1: 0, r2: 6, imm: (bias_addr + w) as i8 });
        a.push(Instr::St { r1: 0, r2: 6, imm: (ACC + w) as i8 });
    }
    match variant {
        TpVariant::Baseline => {
            for kk in 0..k {
                let t = format!("{tag}k{kk}");
                a.push(Instr::Ld { r1: 0, r2: 6, imm: (in_base + kk) as i8 });
                a.push(Instr::Sxt { r1: 1, r2: 0 });
                a.push(Instr::Ld { r1: 2, r2: 6, imm: (col_addr + kk) as i8 });
                // softmul clobbers r5 only among the low regs; r6 == 0
                // survives (softmul uses r0..r5).
                emit_softmul(a, &t, d, p);
                a.push(Instr::Sxt { r1: 5, r2: 4 });
                a.push(Instr::Ld { r1: 0, r2: 6, imm: ACC as i8 });
                a.push(Instr::Add { r1: 0, r2: 3 });
                a.push(Instr::St { r1: 0, r2: 6, imm: ACC as i8 });
                for w in 1..nacc {
                    a.push(Instr::Ld { r1: 0, r2: 6, imm: (ACC + w) as i8 });
                    let src = if w < 2 { 4 } else { 5 };
                    a.push(Instr::Adc { r1: 0, r2: src });
                    a.push(Instr::St { r1: 0, r2: 6, imm: (ACC + w) as i8 });
                }
            }
        }
        TpVariant::Mac { .. } => {
            for kk in 0..k {
                a.push(Instr::Ld { r1: 0, r2: 6, imm: (in_base + kk) as i8 });
                a.push(Instr::Ld { r1: 1, r2: 6, imm: (col_addr + kk) as i8 });
                a.push(Instr::Mac { op: MacOp::Mac, r1: 0, r2: 1 });
            }
            let parts = (32u32.div_ceil(d)) as usize;
            for part in 0..parts {
                a.push(Instr::Mac { op: MacOp::MacRd, r1: 3, r2: part as u8 });
                a.push(Instr::Ld { r1: 0, r2: 6, imm: (ACC + part) as i8 });
                if part == 0 {
                    a.push(Instr::Add { r1: 0, r2: 3 });
                } else {
                    a.push(Instr::Adc { r1: 0, r2: 3 });
                }
                a.push(Instr::St { r1: 0, r2: 6, imm: (ACC + part) as i8 });
            }
        }
    }
    // Copy acc to the score slot (single layer => always last).
    for w in 0..nacc {
        a.push(Instr::Ld { r1: 0, r2: 6, imm: (ACC + w) as i8 });
        a.push(Instr::St { r1: 0, r2: 6, imm: (lay.score_base + j * nacc + w) as i8 });
    }
    // MAC state must be cleared between outputs.
    if matches!(variant, TpVariant::Mac { .. }) {
        a.push(Instr::Mac { op: MacOp::MacClr, r1: 0, r2: 0 });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::translate::UopTpIsa;

    fn tiny_model() -> Model {
        Model::from_json(&crate::ml::model::tests::tiny_model_json()).unwrap()
    }

    /// Idiom-boundary contract with `sim::translate`: the emitted
    /// programs translate completely, and the hot idioms fuse —
    /// `ld/<alu>/st` accumulator updates for the soft-multiply
    /// baseline, `ld/ld/mac` for the MAC variant.
    #[test]
    fn generated_idioms_translate_and_fuse() {
        let m = tiny_model();
        for (variant, want_mac_fuse) in
            [(TpVariant::Baseline, false), (TpVariant::Mac { precision: 8 }, true)]
        {
            let prog = generate(&m, 8, variant).unwrap();
            let stats = prog.translate_stats();
            assert_eq!(stats.untranslatable_blocks, 0, "{variant:?}");
            assert_eq!(stats.translated_instructions, stats.instructions, "{variant:?}");
            assert!(stats.fused > 0, "{variant:?}: no fused superinstructions");
            let mut saw_ld2mac = false;
            let mut saw_ldopst = false;
            for b in &prog.prepared.translated.blocks {
                for u in b.uops.iter() {
                    match u {
                        UopTpIsa::Ld2Mac { .. } => saw_ld2mac = true,
                        UopTpIsa::LdOpSt { .. } => saw_ldopst = true,
                        _ => {}
                    }
                }
            }
            if want_mac_fuse {
                assert!(saw_ld2mac, "{variant:?}: ld/ld/mac did not fuse");
            } else {
                assert!(saw_ldopst, "{variant:?}: ld/<alu>/st did not fuse");
            }
        }
    }

    #[test]
    fn baseline_and_mac_programs_agree_on_scores() {
        use crate::ml::harness;
        let m = tiny_model();
        let xs = vec![vec![0.5f32, 0.25], vec![0.1, -0.3]];
        let base = generate(&m, 8, TpVariant::Baseline).unwrap();
        let mac = generate(&m, 8, TpVariant::Mac { precision: 8 }).unwrap();
        let rb = harness::run_tpisa(&m, &base, &xs).unwrap();
        let rm = harness::run_tpisa(&m, &mac, &xs).unwrap();
        assert_eq!(rb.predictions, rm.predictions);
        for (a, b) in rb.scores.iter().zip(&rm.scores) {
            assert_eq!(a, b);
        }
    }
}
