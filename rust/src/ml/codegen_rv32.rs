//! RV32 code generation: lower a quantised model to a Zero-Riscy
//! program, in three variants (paper Table I rows):
//!
//! * [`Rv32Variant::Baseline`] — scalar `lh/lh/mul/add` inner product
//!   (the baseline 3-cycle multiplier path), 16-bit quantisation.
//! * [`Rv32Variant::Mac32`] — scalar loads feeding the 32-bit MAC unit
//!   (single-cycle multiply-accumulate, no parallelisation), 16-bit
//!   quantisation (bit-identical results to Baseline).
//! * [`Rv32Variant::Simd(p)`] — packed `lw/lw/mac` at precision
//!   p ∈ {16, 8, 4} with 32/p lanes per instruction, p-bit quantisation.
//!
//! Program contract (shared with `ml::harness`):
//!
//! * RAM: scores (i32 accs) at `RAM_BASE`, input at `RAM_BASE + 0x40`,
//!   hidden scratch at `RAM_BASE + 0x100`, packed scratch at `+ 0x180`.
//! * ROM: code at 0, constant data (packed weights) at `DATA_BASE`.
//! * The program halts with `ebreak`; final-layer accumulators are
//!   written as i32 words to the scores region; the harness dequantises
//!   with the last layer's `2^-(fx+fw)` scale and applies the head.

use std::sync::Arc;

use anyhow::{bail, Result};

use super::model::{Model, QLayer};
use super::quant::{pack_vec, qlimits};
use crate::hw::mac_unit::MacConfig;
use crate::isa::rv32::Instr;
use crate::isa::rv32_asm::Asm;
use crate::sim::mem::RAM_BASE;
use crate::sim::prepared::PreparedRv32;

/// Fixed ROM offset where constant data is placed (code must fit below).
pub const DATA_BASE: u32 = 0x2000;

pub const SCORES_OFF: i32 = 0x0;
pub const INPUT_OFF: i32 = 0x40;
pub const HIDDEN_OFF: i32 = 0x100;
pub const PACKED_OFF: i32 = 0x180;
pub const RAM_BYTES: usize = 0x400;

/// Program variant (Table I rows).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rv32Variant {
    Baseline,
    Mac32,
    Simd(u32),
}

impl Rv32Variant {
    /// Quantisation precision of the model tensors this variant runs.
    pub fn quant_precision(&self) -> u32 {
        match self {
            Rv32Variant::Baseline | Rv32Variant::Mac32 => 16,
            Rv32Variant::Simd(p) => *p,
        }
    }

    /// The MAC unit configuration the core must be synthesised with.
    pub fn mac_config(&self) -> Option<MacConfig> {
        match self {
            Rv32Variant::Baseline => None,
            Rv32Variant::Mac32 => Some(MacConfig::new(32, 32)),
            Rv32Variant::Simd(p) => Some(MacConfig::new(32, *p)),
        }
    }

    pub fn label(&self) -> String {
        match self {
            Rv32Variant::Baseline => "baseline".into(),
            Rv32Variant::Mac32 => "mac32".into(),
            Rv32Variant::Simd(p) => format!("simd-p{p}"),
        }
    }
}

/// How the harness must lay out the input vector in RAM.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InputFormat {
    /// One i16 halfword per feature.
    I16,
    /// 32-bit words packed with 32/p lanes of p bits.
    Packed(u32),
}

/// A generated program plus its I/O contract.
#[derive(Debug, Clone)]
pub struct Rv32Program {
    pub code: Vec<Instr>,
    pub rom_data: Vec<u8>,
    /// Shared prepared image (encoded ROM, static mnemonics, MAC
    /// config, `RAM_BYTES` of RAM, pre-translated block cache) — built
    /// once here so the harness constructs simulators without
    /// re-encoding or re-translating the program.
    pub prepared: Arc<PreparedRv32>,
    pub variant: Rv32Variant,
    pub n_scores: usize,
    pub input_format: InputFormat,
    /// Dequantisation scale of the final accumulators: 2^(fx + fw).
    pub score_scale: f64,
    /// ROM cells actually occupied (code + data), for the §IV-B memory
    /// analysis.
    pub rom_cells: usize,
}

impl Rv32Program {
    /// Block-cache statistics of the pre-translated image (blocks,
    /// fused superinstructions, coverage) — the generated idioms
    /// (`lw/lw/mac`, `lh/lh/mul/add`, `addi` stride bumps) sit on known
    /// instruction boundaries, so the translator's peephole pass must
    /// fuse them; `perf_iss` reports these numbers per model.
    pub fn translate_stats(&self) -> &crate::sim::translate::TranslateStats {
        &self.prepared.translated.stats
    }
}

// Register conventions.
const T0: u8 = 5;
const T1: u8 = 6;
const T2: u8 = 7;
const S0: u8 = 8; // x pointer
const S1: u8 = 9; // w pointer
const S2: u8 = 18; // RAM base
const A0: u8 = 10; // accumulator
const A1: u8 = 11; // loop counter

/// Append little-endian bytes of a value at the given element width.
fn push_elem(data: &mut Vec<u8>, v: i64, bytes: usize) {
    for i in 0..bytes {
        data.push(((v >> (8 * i)) & 0xff) as u8);
    }
}

/// Saturating clamp emit: a0 = clamp(a0, qmin, qmax), then optional ReLU.
fn emit_sat_relu(a: &mut Asm, tag: &str, n: u32, relu: bool) {
    let (qmin, qmax) = qlimits(n);
    a.li(T0, qmax as i32);
    a.blt(A0, T0, &format!("sat_hi_{tag}"));
    a.mv(A0, T0);
    a.label(&format!("sat_hi_{tag}"));
    a.li(T0, qmin as i32);
    a.bge(A0, T0, &format!("sat_lo_{tag}"));
    a.mv(A0, T0);
    a.label(&format!("sat_lo_{tag}"));
    if relu {
        a.bge(A0, 0, &format!("relu_{tag}"));
        a.li(A0, 0);
        a.label(&format!("relu_{tag}"));
    }
}

/// Generate the program for `model` under `variant`.
pub fn generate(model: &Model, variant: Rv32Variant) -> Result<Rv32Program> {
    let p = variant.quant_precision();
    let qls: &[QLayer] = model.qlayers(p)?;
    let mut a = Asm::new();
    let mut data: Vec<u8> = Vec::new();

    a.li(S2, RAM_BASE as i32);

    // Per-layer input location/layout inside RAM (offsets from S2).
    // Layer 0 reads the harness-written input region.
    let mut layer_in_off = INPUT_OFF;

    let last_idx = model.layers.len() - 1;
    for (li, (layer, ql)) in model.layers.iter().zip(qls).enumerate() {
        let k = ql.qw.len();
        let n = ql.qb.len();
        let last = li == last_idx;

        match variant {
            Rv32Variant::Baseline | Rv32Variant::Mac32 => {
                // Column-major i16 weights for this layer.
                let col_base: Vec<u32> = (0..n)
                    .map(|j| {
                        let base = DATA_BASE + data.len() as u32;
                        for kk in 0..k {
                            push_elem(&mut data, ql.qw[kk][j], 2);
                        }
                        base
                    })
                    .collect();
                for j in 0..n {
                    let tag = format!("l{li}o{j}");
                    if matches!(variant, Rv32Variant::Mac32) {
                        a.maccl();
                    } else {
                        a.li(A0, 0);
                    }
                    a.addi(S0, S2, layer_in_off);
                    a.li(S1, col_base[j] as i32);
                    a.li(A1, k as i32);
                    a.label(&format!("inner_{tag}"));
                    a.lh(T0, S0, 0);
                    a.lh(T1, S1, 0);
                    if matches!(variant, Rv32Variant::Mac32) {
                        a.mac(T0, T1);
                    } else {
                        a.mul(T2, T0, T1);
                        a.add(A0, A0, T2);
                    }
                    a.addi(S0, S0, 2);
                    a.addi(S1, S1, 2);
                    a.addi(A1, A1, -1);
                    a.bne(A1, 0, &format!("inner_{tag}"));
                    if matches!(variant, Rv32Variant::Mac32) {
                        a.macrd(A0, 0); // low 32 bits (exact by the quant cap)
                    }
                    // Bias.
                    a.li(T0, ql.qb[j] as i32);
                    a.add(A0, A0, T0);
                    finish_output(&mut a, &tag, ql, j, last, layer.relu, p, variant)?;
                }
            }
            Rv32Variant::Simd(prec) => {
                let lanes = (32 / prec) as usize;
                let words_k = k.div_ceil(lanes);
                // Packed weights, column-major.
                let col_base: Vec<u32> = (0..n)
                    .map(|j| {
                        let base = DATA_BASE + data.len() as u32;
                        let col: Vec<i64> = (0..k).map(|kk| ql.qw[kk][j]).collect();
                        for w in pack_vec(&col, prec, 32) {
                            push_elem(&mut data, w as i64, 4);
                        }
                        base
                    })
                    .collect();
                // Layer > 0 at p4 needs explicit nibble packing of the
                // hidden bytes (p16/p8 hidden storage is already packed
                // by memory layout).
                let in_off = if li > 0 && prec == 4 {
                    emit_pack_nibbles(&mut a, li, k, HIDDEN_OFF, PACKED_OFF);
                    PACKED_OFF
                } else {
                    layer_in_off
                };
                for j in 0..n {
                    let tag = format!("l{li}o{j}");
                    a.maccl();
                    a.addi(S0, S2, in_off);
                    a.li(S1, col_base[j] as i32);
                    if words_k <= 3 {
                        // "Entire neurons in a single pass, without
                        // requiring additional control instructions for
                        // loops" (§IV-B c): short packed columns are
                        // unrolled with immediate offsets.
                        for w in 0..words_k {
                            a.lw(T0, S0, 4 * w as i32);
                            a.lw(T1, S1, 4 * w as i32);
                            a.mac(T0, T1);
                        }
                    } else {
                        a.li(A1, words_k as i32);
                        a.label(&format!("inner_{tag}"));
                        a.lw(T0, S0, 0);
                        a.lw(T1, S1, 0);
                        a.mac(T0, T1);
                        a.addi(S0, S0, 4);
                        a.addi(S1, S1, 4);
                        a.addi(A1, A1, -1);
                        a.bne(A1, 0, &format!("inner_{tag}"));
                    }
                    // Read the unit's adder-tree total (paper Eq. 1
                    // acc_total — summed in hardware, Fig. 2).
                    let _ = lanes;
                    a.macrd(A0, crate::sim::mac_model::MacState::TOTAL_LANE as u8);
                    a.li(T0, ql.qb[j] as i32);
                    a.add(A0, A0, T0);
                    finish_output(&mut a, &tag, ql, j, last, layer.relu, p, variant)?;
                }
            }
        }
        layer_in_off = HIDDEN_OFF;
    }
    a.ebreak();

    let code = a.finish()?;
    let code_bytes = code.len() * 4;
    if code_bytes as u32 > DATA_BASE {
        bail!("program too large: {code_bytes} bytes exceeds DATA_BASE");
    }
    let rom_cells = code_bytes + data.len();

    // ROM image: code padding up to DATA_BASE then data.
    let mut rom_data = vec![0u8; DATA_BASE as usize - code_bytes];
    rom_data.extend_from_slice(&data);

    let lastq = &qls[last_idx];
    let prepared = Arc::new(PreparedRv32::new(&code, &rom_data, RAM_BYTES, variant.mac_config()));
    Ok(Rv32Program {
        code,
        rom_data,
        prepared,
        variant,
        n_scores: model.raw_outputs(),
        input_format: match variant {
            Rv32Variant::Baseline | Rv32Variant::Mac32 => InputFormat::I16,
            Rv32Variant::Simd(prec) => InputFormat::Packed(prec),
        },
        score_scale: (1i64 << (lastq.fx + lastq.fw)) as f64,
        rom_cells,
    })
}

/// Epilogue for one output neuron: store the raw accumulator (last
/// layer) or rescale + saturate + ReLU and store to the hidden region.
#[allow(clippy::too_many_arguments)]
fn finish_output(
    a: &mut Asm,
    tag: &str,
    ql: &QLayer,
    j: usize,
    last: bool,
    relu: bool,
    p: u32,
    variant: Rv32Variant,
) -> Result<()> {
    if last {
        a.sw(A0, S2, SCORES_OFF + 4 * j as i32);
        return Ok(());
    }
    // Rescale: acc = (acc + 1 << (shift-1)) >> shift, saturate, ReLU.
    if ql.shift > 0 {
        a.li(T0, 1 << (ql.shift - 1));
        a.add(A0, A0, T0);
        a.srai(A0, A0, ql.shift as i32);
    }
    emit_sat_relu(a, tag, p, relu);
    // Store at the element width of the next layer's loads: i16 for
    // baseline/mac32/p16, i8 for p8/p4 (contiguous elements double as
    // the packed layout for p16/p8; p4 packs explicitly).
    match variant {
        Rv32Variant::Baseline | Rv32Variant::Mac32 => {
            a.push(Instr::Store {
                op: crate::isa::rv32::StoreOp::Sh,
                rs2: A0,
                rs1: S2,
                offset: HIDDEN_OFF + 2 * j as i32,
            });
        }
        Rv32Variant::Simd(16) => {
            a.push(Instr::Store {
                op: crate::isa::rv32::StoreOp::Sh,
                rs2: A0,
                rs1: S2,
                offset: HIDDEN_OFF + 2 * j as i32,
            });
        }
        Rv32Variant::Simd(_) => {
            a.push(Instr::Store {
                op: crate::isa::rv32::StoreOp::Sb,
                rs2: A0,
                rs1: S2,
                offset: HIDDEN_OFF + j as i32,
            });
        }
    }
    Ok(())
}

/// Pack `k` hidden bytes (4-bit values stored as bytes) into nibble
/// words at PACKED_OFF.
fn emit_pack_nibbles(a: &mut Asm, li: usize, k: usize, from_off: i32, to_off: i32) {
    let words = k.div_ceil(8);
    for w in 0..words {
        a.li(A0, 0);
        for lane in 0..8 {
            let idx = w * 8 + lane;
            if idx >= k {
                break;
            }
            a.push(Instr::Load {
                op: crate::isa::rv32::LoadOp::Lbu,
                rd: T0,
                rs1: S2,
                offset: from_off + idx as i32,
            });
            a.push(Instr::OpImm {
                op: crate::isa::rv32::AluOp::And,
                rd: T0,
                rs1: T0,
                imm: 0xf,
            });
            if lane > 0 {
                a.slli(T0, T0, (4 * lane) as i32);
            }
            a.push(Instr::Op { op: crate::isa::rv32::AluOp::Or, rd: A0, rs1: A0, rs2: T0 });
        }
        a.sw(A0, S2, to_off + 4 * w as i32);
        let _ = li;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Value;

    fn tiny_model() -> Model {
        // Reuse the hand-quantised tiny model from model.rs tests, but
        // with variants for 16/8/4 added programmatically.
        let mut m = Model::from_json(&super::super::model::tests::tiny_model_json()).unwrap();
        // Derive 16/4-bit variants by re-quantising the float weights
        // with simple formats (adequate for codegen tests).
        for (n, fx, fw, fy) in [(16u32, 12u32, 12u32, 10u32), (4, 2, 2, 1)] {
            let mut qlayers = Vec::new();
            let mut fxc = fx;
            for (i, l) in m.layers.iter().enumerate() {
                let fyc = if i == m.layers.len() - 1 { 0 } else { fy };
                let qw: Vec<Vec<i64>> = l
                    .w
                    .iter()
                    .map(|row| {
                        row.iter().map(|&v| super::super::quant::quantize(v, fw, n)).collect()
                    })
                    .collect();
                let qb: Vec<i64> = l
                    .b
                    .iter()
                    .map(|&v| super::super::quant::quantize(v, fxc + fw, 32))
                    .collect();
                qlayers.push(QLayer { fx: fxc, fw, fy: fyc, shift: fxc + fw - fyc, qw, qb });
                fxc = fyc;
            }
            m.quantized.push((n, qlayers));
        }
        m
    }

    #[test]
    fn generates_all_variants() {
        let m = tiny_model();
        for v in [
            Rv32Variant::Baseline,
            Rv32Variant::Mac32,
            Rv32Variant::Simd(16),
            Rv32Variant::Simd(8),
            Rv32Variant::Simd(4),
        ] {
            let prog = generate(&m, v).unwrap_or_else(|e| panic!("{v:?}: {e}"));
            assert!(!prog.code.is_empty());
            assert_eq!(prog.n_scores, 1);
            assert!(prog.rom_cells > 0);
        }
    }

    /// Idiom-boundary contract with `sim::translate`: every variant's
    /// emitted program translates completely (no untranslatable
    /// blocks), and the hot idioms fuse — `lw/lw/mac` for the MAC
    /// variants, `lh/lh/mul/add` for the baseline.
    #[test]
    fn generated_idioms_translate_and_fuse() {
        let m = tiny_model();
        for v in [
            Rv32Variant::Baseline,
            Rv32Variant::Mac32,
            Rv32Variant::Simd(16),
            Rv32Variant::Simd(8),
            Rv32Variant::Simd(4),
        ] {
            let prog = generate(&m, v).unwrap();
            let stats = prog.translate_stats();
            assert_eq!(stats.untranslatable_blocks, 0, "{v:?}");
            assert_eq!(stats.translated_instructions, stats.instructions, "{v:?}");
            assert!(stats.fused > 0, "{v:?}: no fused superinstructions");
            let fused_dot = prog.prepared.translated.blocks.iter().any(|b| {
                b.uops.iter().any(|u| {
                    matches!(
                        u,
                        crate::sim::translate::UopRv32::Load2Mac { .. }
                            | crate::sim::translate::UopRv32::Load2MulAdd { .. }
                    )
                })
            });
            assert!(fused_dot, "{v:?}: dot-product idiom did not fuse");
        }
    }

    #[test]
    fn simd_code_is_shorter_per_term_than_baseline() {
        // The paper's §IV-B: SIMD reduces instruction count.  With the
        // tiny model the static code difference is modest, but the MAC
        // variant must not be larger than baseline.
        let m = tiny_model();
        let base = generate(&m, Rv32Variant::Baseline).unwrap();
        let mac = generate(&m, Rv32Variant::Mac32).unwrap();
        assert!(mac.code.len() <= base.code.len() + 4);
    }

    #[test]
    fn score_scale_matches_last_layer() {
        let m = tiny_model();
        let prog = generate(&m, Rv32Variant::Baseline).unwrap();
        let ql = m.qlayers(16).unwrap();
        let last = ql.last().unwrap();
        assert_eq!(prog.score_scale, (1i64 << (last.fx + last.fw)) as f64);
    }
}
