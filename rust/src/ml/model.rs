//! Model representation loaded from the weights JSON emitted by the AOT
//! pipeline (`python/compile/aot.py`).  Every quantisation parameter and
//! integer tensor is baked in there, so the rust side shares the exact
//! numbers the Pallas kernel was lowered with.

use anyhow::{bail, Context, Result};

use crate::util::json::Value;

/// Output head (mirrors `python/compile/model.py`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Head {
    /// Classification logits; prediction = argmax + label_offset.
    Argmax,
    /// One-vs-one SVM pair decisions voted into class counts.
    OvoVote,
    /// Regression scalar; prediction = clamped round.
    Round,
}

/// One dense layer: float tensors plus the per-precision quantised
/// tensors and formats.
#[derive(Debug, Clone)]
pub struct Layer {
    pub w: Vec<Vec<f64>>, // [K][N]
    pub b: Vec<f64>,      // [N]
    pub relu: bool,
}

/// Quantised view of one layer at one precision.
#[derive(Debug, Clone)]
pub struct QLayer {
    pub fx: u32,
    pub fw: u32,
    pub fy: u32,
    pub shift: u32,
    pub qw: Vec<Vec<i64>>, // [K][N]
    pub qb: Vec<i64>,      // [N]
}

/// A loaded model with quantised variants for each precision.
#[derive(Debug, Clone)]
pub struct Model {
    pub name: String,
    pub dataset: String,
    pub head: Head,
    pub arch: Vec<usize>,
    pub n_classes: usize,
    pub label_offset: i64,
    pub ovo_pairs: Vec<(usize, usize)>,
    pub layers: Vec<Layer>,
    /// Quantised layers keyed by precision (32/16/8/4).
    pub quantized: Vec<(u32, Vec<QLayer>)>,
    pub float_accuracy: f64,
}

impl Model {
    pub fn from_json(v: &Value) -> Result<Model> {
        let head = match v.get("head")?.as_str()? {
            "argmax" => Head::Argmax,
            "ovo_vote" => Head::OvoVote,
            "round" => Head::Round,
            h => bail!("unknown head {h:?}"),
        };
        let layers = v
            .get("layers")?
            .as_arr()?
            .iter()
            .map(|l| {
                Ok(Layer {
                    w: l.get("w")?.as_f64_mat()?,
                    b: l.get("b")?.as_f64_vec()?,
                    relu: l.get("relu")?.as_bool()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let mut quantized = Vec::new();
        for (prec, qls) in v.get("quantized")?.as_obj()? {
            let n: u32 = prec.parse().context("precision key")?;
            let qlayers = qls
                .as_arr()?
                .iter()
                .map(|q| {
                    Ok(QLayer {
                        fx: q.get("fx")?.as_usize()? as u32,
                        fw: q.get("fw")?.as_usize()? as u32,
                        fy: q.get("fy")?.as_usize()? as u32,
                        shift: q.get("shift")?.as_usize()? as u32,
                        qw: q.get("qw")?.as_i64_mat()?,
                        qb: q.get("qb")?.as_i64_vec()?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            quantized.push((n, qlayers));
        }
        Ok(Model {
            name: v.get("name")?.as_str()?.to_string(),
            dataset: v.get("dataset")?.as_str()?.to_string(),
            head,
            arch: v
                .get("arch")?
                .as_i64_vec()?
                .into_iter()
                .map(|x| x as usize)
                .collect(),
            n_classes: v.get("n_classes")?.as_usize()?,
            label_offset: v.get("label_offset")?.as_i64()?,
            ovo_pairs: v
                .get("ovo_pairs")?
                .as_i64_mat()?
                .into_iter()
                .map(|p| (p[0] as usize, p[1] as usize))
                .collect(),
            layers,
            quantized,
            float_accuracy: v.get("float_accuracy")?.as_f64()?,
        })
    }

    pub fn load(path: impl AsRef<std::path::Path>) -> Result<Model> {
        Model::from_json(&Value::from_file(path)?)
    }

    /// Quantised layers for a precision.
    pub fn qlayers(&self, precision: u32) -> Result<&[QLayer]> {
        self.quantized
            .iter()
            .find(|(p, _)| *p == precision)
            .map(|(_, q)| q.as_slice())
            .with_context(|| format!("{}: no quantised variant for p{precision}", self.name))
    }

    /// Number of score outputs (C in the uniform [B, C] interface).
    pub fn n_outputs(&self) -> usize {
        match self.head {
            Head::Argmax => self.n_classes,
            Head::OvoVote => self.n_classes, // votes per class
            Head::Round => 1,
        }
    }

    /// Width of the last dense layer (pre-head).
    pub fn raw_outputs(&self) -> usize {
        *self.arch.last().unwrap()
    }

    /// Reference quantised inference (plain rust integers) — the oracle
    /// the ISS-executed programs and the PJRT executables are checked
    /// against.  Returns the uniform score vector.
    pub fn quantized_forward(&self, x: &[f32], precision: u32) -> Result<Vec<f64>> {
        let qls = self.qlayers(precision)?;
        let mut h: Vec<i64> =
            x.iter().map(|&v| super::quant::quantize(v as f64, qls[0].fx, precision)).collect();
        let mut raw: Vec<f64> = Vec::new();
        for (i, (layer, ql)) in self.layers.iter().zip(qls).enumerate() {
            let k = ql.qw.len();
            let n = ql.qb.len();
            anyhow::ensure!(h.len() == k, "fan-in mismatch");
            let last = i == self.layers.len() - 1;
            let mut next = Vec::with_capacity(n);
            for j in 0..n {
                let mut acc: i64 = ql.qb[j];
                for kk in 0..k {
                    let prod = h[kk].wrapping_mul(ql.qw[kk][j]);
                    acc = acc.wrapping_add(prod);
                }
                if last {
                    next.push(acc);
                } else {
                    let mut y = super::quant::rescale(acc, ql.shift, precision);
                    if layer.relu {
                        y = y.max(0);
                    }
                    next.push(y);
                }
            }
            if last {
                let scale = (1i64 << (ql.fx + ql.fw)) as f64;
                raw = next.iter().map(|&a| a as f64 / scale).collect();
            } else {
                h = next;
            }
        }
        Ok(self.head_scores(&raw))
    }

    /// Map the last layer's float outputs to the uniform score vector
    /// (mirrors `model._head_scores`).
    pub fn head_scores(&self, raw: &[f64]) -> Vec<f64> {
        match self.head {
            Head::Argmax | Head::Round => raw.to_vec(),
            Head::OvoVote => {
                let mut votes = vec![0.0f64; self.n_classes];
                for (p, &(i, j)) in self.ovo_pairs.iter().enumerate() {
                    if raw[p] >= 0.0 {
                        votes[i] += 1.0;
                    } else {
                        votes[j] += 1.0;
                    }
                }
                votes
            }
        }
    }

    /// Scores -> predicted label (mirrors `model.predict_from_scores`).
    pub fn predict(&self, scores: &[f64]) -> i64 {
        match self.head {
            Head::Round => {
                let v = (scores[0] + 0.5).floor() as i64;
                v.clamp(self.label_offset, self.label_offset + self.n_classes as i64 - 1)
            }
            Head::Argmax | Head::OvoVote => {
                let mut best = 0;
                for (i, &s) in scores.iter().enumerate() {
                    if s > scores[best] {
                        best = i;
                    }
                }
                best as i64 + self.label_offset
            }
        }
    }

    /// Float reference forward (f64 arithmetic).
    pub fn float_forward(&self, x: &[f32]) -> Vec<f64> {
        let mut h: Vec<f64> = x.iter().map(|&v| v as f64).collect();
        for layer in &self.layers {
            let k = layer.w.len();
            let n = layer.b.len();
            let mut next = vec![0.0f64; n];
            for j in 0..n {
                let mut acc = layer.b[j];
                for kk in 0..k {
                    acc += h[kk] * layer.w[kk][j];
                }
                next[j] = if layer.relu { acc.max(0.0) } else { acc };
            }
            h = next;
        }
        self.head_scores(&h)
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;

    pub(crate) fn tiny_model_json() -> Value {
        // A 2-in -> 2-hidden -> 1-out regression model with hand
        // quantisation at p8: fx=6, fw=5, fy=4, shift=7.
        Value::parse(
            r#"{
            "name": "tiny", "dataset": "synth", "task": "regression",
            "head": "round", "arch": [2, 2, 1], "n_classes": 6,
            "label_offset": 3, "ovo_pairs": [], "calib": [1.0, 2.0, 8.0],
            "float_accuracy": 0.5,
            "layers": [
                {"relu": true, "w": [[1.0, -0.5], [0.25, 1.0]], "b": [0.125, 0.0]},
                {"relu": false, "w": [[2.0], [-1.0]], "b": [0.5]}
            ],
            "quantized": {
                "8": [
                    {"fx": 6, "fw": 5, "fy": 4, "shift": 7,
                     "qw": [[32, -16], [8, 32]], "qb": [256, 0]},
                    {"fx": 4, "fw": 4, "fy": 4, "shift": 4,
                     "qw": [[32], [-16]], "qb": [128]}
                ]
            }
        }"#,
        )
        .unwrap()
    }

    #[test]
    fn loads_and_runs_quantized() {
        let m = Model::from_json(&tiny_model_json()).unwrap();
        assert_eq!(m.arch, vec![2, 2, 1]);
        assert_eq!(m.head, Head::Round);
        // Hand-compute: x = [0.5, 0.25] -> qx = [32, 16] (fx=6).
        // h1 acc = 32*32 + 16*8 + 256 = 1408; rescale >>7 = 11
        // h2 acc = 32*-16 + 16*32 + 0 = 0; rescale = 0
        // out acc = 11*32 + 0*-16 + 128 = 480; scale 2^8 -> 1.875
        let scores = m.quantized_forward(&[0.5, 0.25], 8).unwrap();
        assert!((scores[0] - 480.0 / 256.0).abs() < 1e-12, "{scores:?}");
        // predict: round(1.875) = 2, clamped to [3, 8] -> 3.
        assert_eq!(m.predict(&scores), 3);
    }

    #[test]
    fn float_forward_close_to_quantized() {
        let m = Model::from_json(&tiny_model_json()).unwrap();
        let f = m.float_forward(&[0.5, 0.25]);
        let q = m.quantized_forward(&[0.5, 0.25], 8).unwrap();
        assert!((f[0] - q[0]).abs() < 0.2, "float {f:?} vs q {q:?}");
    }

    #[test]
    fn missing_precision_errors() {
        let m = Model::from_json(&tiny_model_json()).unwrap();
        assert!(m.qlayers(16).is_err());
        assert!(m.qlayers(8).is_ok());
    }

    #[test]
    fn ovo_head_votes() {
        let mut m = Model::from_json(&tiny_model_json()).unwrap();
        m.head = Head::OvoVote;
        m.n_classes = 3;
        m.label_offset = 0;
        m.ovo_pairs = vec![(0, 1), (0, 2), (1, 2)];
        let votes = m.head_scores(&[1.0, 1.0, 1.0]);
        assert_eq!(votes, vec![2.0, 1.0, 0.0]);
        let votes = m.head_scores(&[-1.0, -1.0, -1.0]);
        assert_eq!(votes, vec![0.0, 1.0, 2.0]);
        assert_eq!(m.predict(&votes), 2);
    }

    #[test]
    fn argmax_tie_breaks_low() {
        let mut m = Model::from_json(&tiny_model_json()).unwrap();
        m.head = Head::Argmax;
        m.label_offset = 0;
        assert_eq!(m.predict(&[1.0, 1.0, 0.5]), 0);
    }
}
