//! `artifacts/manifest.json` — the index the AOT pipeline writes: models,
//! HLO paths per precision, python-side accuracies (the cross-check
//! reference for the coordinator), and the packed-MAC unit artifacts.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::Value;

#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub name: String,
    pub dataset: String,
    pub arch: Vec<usize>,
    pub n_test: usize,
    pub float_accuracy: f64,
    /// Accuracy measured by the python (jnp oracle) eval per precision.
    pub quant_accuracy: BTreeMap<u32, f64>,
    /// HLO artifact path per variant key ("float", "p32", ...).
    pub hlo: BTreeMap<String, PathBuf>,
    pub weights: PathBuf,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub batch: usize,
    pub precisions: Vec<u32>,
    pub models: Vec<ModelEntry>,
    /// Packed SIMD-MAC unit HLOs: precision -> (path, words).
    pub mac_units: BTreeMap<u32, (PathBuf, usize)>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let v = Value::from_file(dir.join("manifest.json"))?;
        let batch = v.get("batch")?.as_usize()?;
        let precisions =
            v.get("precisions")?.as_i64_vec()?.into_iter().map(|p| p as u32).collect();
        let mut models = Vec::new();
        for m in v.get("models")?.as_arr()? {
            let mut hlo = BTreeMap::new();
            for (k, p) in m.get("hlo")?.as_obj()? {
                hlo.insert(k.clone(), dir.join(p.as_str()?));
            }
            let mut quant_accuracy = BTreeMap::new();
            for (k, a) in m.get("quant_accuracy")?.as_obj()? {
                quant_accuracy.insert(k.parse::<u32>().context("precision key")?, a.as_f64()?);
            }
            models.push(ModelEntry {
                name: m.get("name")?.as_str()?.to_string(),
                dataset: m.get("dataset")?.as_str()?.to_string(),
                arch: m.get("arch")?.as_i64_vec()?.into_iter().map(|x| x as usize).collect(),
                n_test: m.get("n_test")?.as_usize()?,
                float_accuracy: m.get("float_accuracy")?.as_f64()?,
                quant_accuracy,
                hlo,
                weights: dir.join(m.get("weights")?.as_str()?),
            });
        }
        let mut mac_units = BTreeMap::new();
        for (k, u) in v.get("mac_units")?.as_obj()? {
            mac_units.insert(
                k.parse::<u32>().context("mac unit precision")?,
                (dir.join(u.get("path")?.as_str()?), u.get("words")?.as_usize()?),
            );
        }
        Ok(Manifest { dir, batch, precisions, models, mac_units })
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models
            .iter()
            .find(|m| m.name == name)
            .with_context(|| format!("model {name:?} not in manifest"))
    }

    pub fn data_dir(&self) -> PathBuf {
        self.dir.join("data")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_manifest() {
        let dir = std::env::temp_dir().join(format!("pbsp-man-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"batch": 256, "precisions": [32, 16],
                "models": [{"name": "m1", "dataset": "d", "head": "argmax",
                  "arch": [4, 2], "n_classes": 2, "label_offset": 0,
                  "n_test": 10, "float_accuracy": 0.9,
                  "weights": "weights/m1.json",
                  "hlo": {"float": "hlo/m1_float.hlo.txt", "p16": "hlo/m1_p16.hlo.txt"},
                  "quant_accuracy": {"16": 0.9, "32": 0.9}}],
                "mac_units": {"16": {"path": "hlo/mac16.hlo.txt", "words": 64}}}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.batch, 256);
        assert_eq!(m.models.len(), 1);
        let e = m.model("m1").unwrap();
        assert_eq!(e.quant_accuracy[&16], 0.9);
        assert!(e.hlo["p16"].ends_with("hlo/m1_p16.hlo.txt"));
        assert_eq!(m.mac_units[&16].1, 64);
        assert!(m.model("nope").is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
