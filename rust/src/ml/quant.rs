//! Fixed-point contract, rust side — mirrors `python/compile/quant.py`
//! exactly (round-half-up quantisation, saturating rescale, lane
//! packing).  The quantisation *parameters* (fx/fw/fy/shift) are baked
//! into the weights JSON by the AOT pipeline, so rust never re-derives
//! them from floats; the value-level operations here must be
//! bit-identical to the python contract and are property-tested against
//! hand oracles.

/// Quantisation limits of an n-bit signed format.
pub fn qlimits(n: u32) -> (i64, i64) {
    (-(1i64 << (n - 1)), (1i64 << (n - 1)) - 1)
}

/// Quantise a float to n-bit fixed point with f fractional bits
/// (round-half-up: floor(v * 2^f + 0.5), saturating).
pub fn quantize(v: f64, f: u32, n: u32) -> i64 {
    let (qmin, qmax) = qlimits(n);
    let q = (v * (1i64 << f) as f64 + 0.5).floor();
    if q < qmin as f64 {
        qmin
    } else if q > qmax as f64 {
        qmax
    } else {
        q as i64
    }
}

pub fn dequantize(q: i64, f: u32) -> f64 {
    q as f64 / (1i64 << f) as f64
}

/// Saturating round-half-up arithmetic right shift to n bits (the
/// hardware rescaler between layers).
pub fn rescale(acc: i64, shift: u32, n: u32) -> i64 {
    let v = if shift > 0 { (acc + (1i64 << (shift - 1))) >> shift } else { acc };
    let (qmin, qmax) = qlimits(n);
    v.clamp(qmin, qmax)
}

/// Pack `lanes` n-bit values (little-endian lane order: lane 0 in the
/// least-significant bits) into one datapath word.
pub fn pack_lanes(vals: &[i64], n: u32, datapath: u32) -> u64 {
    let lanes = (datapath / n).max(1) as usize;
    assert!(vals.len() <= lanes, "too many lanes");
    let mask = if n == 64 { u64::MAX } else { (1u64 << n) - 1 };
    let mut w = 0u64;
    for (i, &v) in vals.iter().enumerate() {
        w |= ((v as u64) & mask) << (n * i as u32);
    }
    w
}

/// Unpack a word into sign-extended lanes.
pub fn unpack_lanes(word: u64, n: u32, datapath: u32) -> Vec<i64> {
    let lanes = (datapath / n).max(1) as usize;
    (0..lanes).map(|i| crate::sim::mac_model::sext(word >> (n * i as u32), n)).collect()
}

/// Pack a whole vector into datapath words (zero-padding the tail —
/// zero lanes contribute nothing to a MAC).
pub fn pack_vec(vals: &[i64], n: u32, datapath: u32) -> Vec<u64> {
    let lanes = (datapath / n).max(1) as usize;
    vals.chunks(lanes).map(|c| pack_lanes(c, n, datapath)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantize_matches_python_contract() {
        // Mirrors python test_quantize_round_half_up.
        assert_eq!(quantize(0.5, 0, 8), 1);
        assert_eq!(quantize(-0.5, 0, 8), 0);
        assert_eq!(quantize(1.5, 0, 8), 2);
        assert_eq!(quantize(-1.5, 0, 8), -1);
        assert_eq!(quantize(1e9, 4, 8), 127);
        assert_eq!(quantize(-1e9, 4, 8), -128);
        // fx = 6: 0.5 * 64 = 32.
        assert_eq!(quantize(0.5, 6, 8), 32);
    }

    #[test]
    fn rescale_matches_python_contract() {
        // floor(acc / 2^s + 0.5) with saturation.
        assert_eq!(rescale(1000, 3, 8), 125);
        assert_eq!(rescale(1020, 3, 8), 127); // saturates
        assert_eq!(rescale(-3000, 3, 8), -128);
        assert_eq!(rescale(12, 2, 8), 3);
        assert_eq!(rescale(14, 2, 8), 4); // 3.5 rounds up
        assert_eq!(rescale(-14, 2, 8), -3); // -3.5 rounds toward zero/up
        assert_eq!(rescale(300, 0, 8), 127);
    }

    #[test]
    fn prop_rescale_equals_float_oracle() {
        crate::util::prop::check("rescale oracle", 500, |rng| {
            let acc = rng.range_i64(-(1 << 40), 1 << 40);
            let shift = rng.range_i64(0, 24) as u32;
            let n = *rng.choice(&[4u32, 8, 16, 32]);
            let got = rescale(acc, shift, n);
            let want = {
                let v = (acc as f64 / (1i64 << shift) as f64 + 0.5).floor() as i64;
                let (lo, hi) = qlimits(n);
                v.clamp(lo, hi)
            };
            if got != want {
                return Err(format!("acc {acc} shift {shift} n {n}: {got} != {want}"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_pack_unpack_roundtrip() {
        crate::util::prop::check("pack/unpack", 500, |rng| {
            let n = *rng.choice(&[4u32, 8, 16, 32]);
            let d = 32u32;
            let lanes = (d / n) as usize;
            let (lo, hi) = qlimits(n);
            let vals: Vec<i64> = (0..lanes).map(|_| rng.range_i64(lo, hi)).collect();
            let w = pack_lanes(&vals, n, d);
            if w > u32::MAX as u64 {
                return Err(format!("word {w:#x} exceeds 32 bits"));
            }
            let back = unpack_lanes(w, n, d);
            if back != vals {
                return Err(format!("{vals:?} -> {w:#x} -> {back:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn pack_vec_pads_with_zeros() {
        let words = pack_vec(&[1, 2, 3], 16, 32);
        assert_eq!(words.len(), 2);
        assert_eq!(words[0], (2 << 16) | 1);
        assert_eq!(words[1], 3);
    }

    #[test]
    fn pack_lane_order_matches_python() {
        // python test_pack_lane_order: lane 0 in the LSBs.
        assert_eq!(pack_lanes(&[1, 2], 16, 32), (2 << 16) | 1);
        assert_eq!(pack_lanes(&[-1], 16, 32), 0xffff);
    }
}
