//! Hermetic artifact fixtures: a tiny checked-in stand-in for the AOT
//! artifact tree, so `cargo test` exercises the full manifest → model →
//! codegen → service path with zero external setup.
//!
//! The real pipeline (`make artifacts`) needs JAX to train the six
//! evaluation models and lower them to HLO text; CI and fresh checkouts
//! have neither.  The repository therefore ships `artifacts-fixture/`:
//! the same `manifest.json` schema and directory layout as the AOT
//! output, with miniature versions of the six paper models and *stub*
//! HLO files — small JSON descriptors (see [`StubHlo`]) that the default
//! `runtime::pjrt` backend interprets against the in-crate references
//! (`Model::quantized_forward` / `Model::float_forward`, and the
//! `sim::mac_model` functional MAC model).  The fixture's recorded
//! accuracies are computed by a bit-exact replica of the rust
//! fixed-point contract, so the service-vs-manifest equality tests hold
//! on the fixture exactly as they do on real artifacts.
//!
//! Regenerate with `python3 tools/gen_fixture.py` (deterministic).
//!
//! Resolution order lives in [`crate::artifacts_dir`]: an explicit
//! `$PBSP_ARTIFACTS` wins, then a real `artifacts/` tree found by
//! walking up from the current directory, then this fixture.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Value;

/// Directory name of the checked-in fixture tree (repository root).
pub const FIXTURE_DIR_NAME: &str = "artifacts-fixture";

/// Walk up from `start` looking for a `<dir_name>/manifest.json` tree;
/// the shared ancestor walk behind [`crate::artifacts_dir`] (used for
/// both the real `artifacts/` tree and this fixture).
pub fn find_up_from(start: PathBuf, dir_name: &str) -> Option<PathBuf> {
    let mut dir = start;
    loop {
        let cand = dir.join(dir_name);
        if cand.join("manifest.json").is_file() {
            return Some(cand);
        }
        if !dir.pop() {
            return None;
        }
    }
}

/// Walk up from the current directory looking for the checked-in
/// fixture tree; `None` when no `artifacts-fixture/manifest.json` is
/// reachable.
pub fn find_fixture_dir() -> Option<PathBuf> {
    find_up_from(std::env::current_dir().ok()?, FIXTURE_DIR_NAME)
}

/// Does this manifest point at stub artifacts (interpretable by the
/// default runtime backend) rather than real HLO text?  Service-level
/// tests use this to skip cleanly when real AOT output is present but
/// the crate was built without `--features xla`.
pub fn manifest_is_stub(man: &crate::ml::manifest::Manifest) -> bool {
    man.models
        .first()
        .and_then(|e| e.hlo.values().next())
        .map(|p| StubHlo::from_file(p).is_ok())
        .unwrap_or(false)
}

/// A parsed stub-HLO descriptor.
///
/// Stub artifacts are JSON objects carrying a `"pbsp_hlo_stub": 1`
/// marker; anything else under `hlo/` is treated as real HLO text and
/// requires the `xla` backend.  Two kinds exist:
///
/// ```text
/// {"pbsp_hlo_stub": 1, "kind": "model",
///  "weights": "../weights/<name>.json", "variant": "float" | "p<N>"}
/// {"pbsp_hlo_stub": 1, "kind": "mac_unit",
///  "datapath": 32, "precision": 8, "words": 64}
/// ```
///
/// Relative `weights` paths resolve against the stub file's directory.
#[derive(Debug, Clone)]
pub enum StubHlo {
    /// A model executable: evaluate `weights` at `variant` ("float" or
    /// "p32"/"p16"/"p8"/"p4").
    Model { weights: PathBuf, variant: String },
    /// A packed SIMD-MAC unit (two `s32[words]` operand streams in,
    /// `s32[lanes]` accumulators out).
    MacUnit { datapath: u32, precision: u32, words: usize },
}

impl StubHlo {
    /// Cheap sniff: does this text look like a stub descriptor?
    pub fn is_stub_text(text: &str) -> bool {
        text.trim_start().starts_with('{') && text.contains("\"pbsp_hlo_stub\"")
    }

    /// Parse a stub artifact file; errors on real HLO text with a
    /// pointer at the `xla` feature.
    pub fn from_file(path: &Path) -> Result<StubHlo> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        if !Self::is_stub_text(&text) {
            bail!(
                "{} is not a PBSP stub artifact; executing real HLO text \
                 requires the `xla` cargo feature (see runtime::pjrt)",
                path.display()
            );
        }
        let v = Value::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
        let base = path.parent().unwrap_or_else(|| Path::new("."));
        match v.get("kind")?.as_str()? {
            "model" => Ok(StubHlo::Model {
                weights: base.join(v.get("weights")?.as_str()?),
                variant: v.get("variant")?.as_str()?.to_string(),
            }),
            "mac_unit" => Ok(StubHlo::MacUnit {
                datapath: v.get("datapath")?.as_usize()? as u32,
                precision: v.get("precision")?.as_usize()? as u32,
                words: v.get("words")?.as_usize()?,
            }),
            k => bail!("unknown stub kind {k:?} in {}", path.display()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::manifest::Manifest;
    use crate::ml::model::Model;

    #[test]
    fn fixture_manifest_round_trips() {
        let dir = find_fixture_dir().expect("checked-in artifacts-fixture/ missing");
        let man = Manifest::load(&dir).unwrap();
        assert_eq!(man.models.len(), 6, "the six paper models");
        assert_eq!(man.precisions, vec![32, 16, 8, 4]);
        assert_eq!(man.mac_units.len(), 4);
        let entry = man.model("mlp_c_cardio").unwrap();
        assert!(entry.hlo.contains_key("float") && entry.hlo.contains_key("p16"));
        // Weights load and expose every manifest precision.
        let model = Model::load(&entry.weights).unwrap();
        for &p in &man.precisions {
            assert!(model.qlayers(p).is_ok(), "p{p} variant missing");
        }
        // Datasets load with matching feature counts and sizes.
        for e in &man.models {
            let ds =
                crate::ml::dataset::Dataset::load(man.data_dir(), &e.dataset, "test").unwrap();
            assert_eq!(ds.n_features(), e.arch[0], "{}", e.name);
            assert_eq!(ds.len(), e.n_test, "{}", e.name);
        }
    }

    #[test]
    fn fixture_stub_files_parse() {
        let dir = find_fixture_dir().expect("checked-in artifacts-fixture/ missing");
        let man = Manifest::load(&dir).unwrap();
        for e in &man.models {
            for (variant, path) in &e.hlo {
                match StubHlo::from_file(path).unwrap() {
                    StubHlo::Model { weights, variant: v } => {
                        assert_eq!(&v, variant);
                        assert!(weights.is_file(), "{} missing", weights.display());
                    }
                    other => panic!("{variant}: expected a model stub, got {other:?}"),
                }
            }
        }
        for (&p, (path, man_words)) in &man.mac_units {
            match StubHlo::from_file(path).unwrap() {
                StubHlo::MacUnit { datapath, precision, words } => {
                    assert_eq!(datapath, 32);
                    assert_eq!(precision, p);
                    assert_eq!(words, *man_words);
                }
                other => panic!("p{p}: expected a mac_unit stub, got {other:?}"),
            }
        }
        assert!(manifest_is_stub(&man));
    }

    #[test]
    fn real_hlo_text_is_rejected() {
        let dir = std::env::temp_dir().join(format!("pbsp-stub-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("real.hlo.txt");
        std::fs::write(&path, "HloModule jit_forward\n\nENTRY main { ... }\n").unwrap();
        let err = StubHlo::from_file(&path).unwrap_err().to_string();
        assert!(err.contains("xla"), "error should point at the xla feature: {err}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn artifacts_dir_env_override_beats_walking() {
        // Exercises resolve_artifacts_dir (the deterministic core of
        // artifacts_dir) directly: mutating $PBSP_ARTIFACTS in-process
        // would race other test threads' getenv calls.
        let cwd = std::env::current_dir().unwrap();
        // Walking alone finds a tree (the checked-in fixture at minimum,
        // or a real artifacts/ when one was built)...
        let walked = crate::resolve_artifacts_dir(None, cwd.clone()).unwrap();
        assert!(walked.ends_with("artifacts") || walked.ends_with(FIXTURE_DIR_NAME));
        // ...but an explicit override short-circuits the walk entirely,
        // without even requiring the directory to exist (mirroring the
        // $PBSP_ARTIFACTS contract).
        let over = PathBuf::from("/nonexistent/pbsp-override/artifacts");
        let got = crate::resolve_artifacts_dir(Some(over.clone()), cwd).unwrap();
        assert_eq!(got, over);
    }
}
