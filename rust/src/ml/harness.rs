//! ISS execution harness: runs a generated program on the matching
//! simulator for a batch of samples, handling input quantisation /
//! packing, score readout, dequantisation and the prediction head.
//!
//! This is the "Modelsim + testbench" analogue of workflow step ④, and
//! the bit-exactness cross-check target for the PJRT path: for every
//! (model, precision) the ISS scores must equal the HLO executable's
//! scores exactly.
//!
//! [`run_rv32_on`] / [`run_tpisa_on`] shard a batch across a thread
//! pool (each sample runs in its own ISS instance anyway); the sharded
//! results merge in sample order, so they are interchangeable with the
//! sequential [`run_rv32`] / [`run_tpisa`].

use anyhow::{ensure, Context, Result};

use super::codegen_rv32::{InputFormat, Rv32Program, RAM_BYTES, SCORES_OFF};
use super::codegen_tpisa::TpIsaProgram;
use super::model::Model;
use super::quant::{pack_vec, quantize};
use crate::sim::mem::RAM_BASE;
use crate::sim::tpisa::TpIsa;
use crate::sim::trace::Profile;
use crate::sim::zero_riscy::{Halt, ZeroRiscy};
use crate::util::threadpool::ThreadPool;

/// Result of running a batch through an ISS.
#[derive(Debug, Clone)]
pub struct BatchRun {
    /// Uniform score vectors (post-head), one per sample.
    pub scores: Vec<Vec<f64>>,
    pub predictions: Vec<i64>,
    /// Aggregated execution profile.
    pub profile: Profile,
    /// Cycles per sample (mean).
    pub cycles_per_sample: f64,
}

/// Quantise + lay out one input vector per the program's contract.
fn input_words_rv32(model: &Model, prog: &Rv32Program, x: &[f32]) -> Result<Vec<u8>> {
    let p = prog.variant.quant_precision();
    let fx = model.qlayers(p)?[0].fx;
    let qx: Vec<i64> = x.iter().map(|&v| quantize(v as f64, fx, p)).collect();
    let mut bytes = Vec::new();
    match prog.input_format {
        InputFormat::I16 => {
            for q in qx {
                bytes.extend_from_slice(&(q as i16).to_le_bytes());
            }
        }
        InputFormat::Packed(prec) => {
            for w in pack_vec(&qx, prec, 32) {
                bytes.extend_from_slice(&(w as u32).to_le_bytes());
            }
        }
    }
    Ok(bytes)
}

/// Run a batch of samples through the Zero-Riscy ISS.
pub fn run_rv32(model: &Model, prog: &Rv32Program, xs: &[Vec<f32>]) -> Result<BatchRun> {
    let mut scores = Vec::with_capacity(xs.len());
    let mut predictions = Vec::with_capacity(xs.len());
    let mut profile = Profile::default();
    for x in xs {
        let mut sim =
            ZeroRiscy::new(&prog.code, &prog.rom_data, RAM_BYTES, prog.variant.mac_config());
        let input = input_words_rv32(model, prog, x)?;
        for (i, b) in input.iter().enumerate() {
            sim.mem
                .store_u8(RAM_BASE + super::codegen_rv32::INPUT_OFF as u32 + i as u32, *b)?;
        }
        let halt = sim.run(50_000_000).context("ISS run")?;
        ensure!(halt == Halt::Break, "program did not halt cleanly: {halt:?}");
        let mut raw = Vec::with_capacity(prog.n_scores);
        for j in 0..prog.n_scores {
            let acc =
                sim.mem.load_u32(RAM_BASE + SCORES_OFF as u32 + 4 * j as u32)? as i32 as i64;
            raw.push(acc as f64 / prog.score_scale);
        }
        let s = model.head_scores(&raw);
        predictions.push(model.predict(&s));
        scores.push(s);
        profile.merge(&sim.profile);
    }
    let cps = profile.cycles as f64 / xs.len().max(1) as f64;
    Ok(BatchRun { scores, predictions, profile, cycles_per_sample: cps })
}

/// Run a batch through the TP-ISA ISS.
pub fn run_tpisa(model: &Model, prog: &TpIsaProgram, xs: &[Vec<f32>]) -> Result<BatchRun> {
    let p = prog.quant_precision;
    let fx = model.qlayers(p)?[0].fx;
    let mut scores = Vec::with_capacity(xs.len());
    let mut predictions = Vec::with_capacity(xs.len());
    let mut profile = Profile::default();
    for x in xs {
        let mut sim = TpIsa::new(prog.datapath, &prog.code, prog.dmem_words, prog.mac_config());
        // Preload constants (weights, biases, rounding constants).
        for (addr, v) in prog.dmem_image.iter().enumerate() {
            sim.dmem.store(addr as i64, *v)?;
        }
        // Input.
        let qx: Vec<i64> = x.iter().map(|&v| quantize(v as f64, fx, p)).collect();
        let words: Vec<u64> = if prog.packed_input {
            pack_vec(&qx, p, prog.datapath)
        } else {
            qx.iter().map(|&q| q as u64).collect()
        };
        for (i, w) in words.iter().enumerate() {
            sim.dmem.store(prog.input_base as i64 + i as i64, *w)?;
        }
        let halt = sim.run(500_000_000).context("TP-ISA run")?;
        ensure!(halt == crate::sim::tpisa::Halt::Halted, "did not halt: {halt:?}");
        // Scores: nacc d-bit chunks per output, little-endian.
        let nacc = (32 / prog.datapath).max(1) as usize;
        let mut raw = Vec::with_capacity(prog.n_scores);
        for j in 0..prog.n_scores {
            let mut acc: u64 = 0;
            for wi in 0..nacc {
                let chunk = sim.dmem.load((prog.score_base + j * nacc + wi) as i64)?;
                acc |= chunk << (prog.datapath * wi as u32);
            }
            let acc = crate::sim::mac_model::sext(acc, 32);
            raw.push(acc as f64 / prog.score_scale);
        }
        let s = model.head_scores(&raw);
        predictions.push(model.predict(&s));
        scores.push(s);
        profile.merge(&sim.profile);
    }
    let cps = profile.cycles as f64 / xs.len().max(1) as f64;
    Ok(BatchRun { scores, predictions, profile, cycles_per_sample: cps })
}

/// Shard size for parallel batch runs: oversubscribe the pool 4x so
/// uneven per-sample cost (ReLU/branch paths) load-balances.
fn shard_size(n_samples: usize, threads: usize) -> usize {
    n_samples.div_ceil(threads.max(1) * 4).max(1)
}

/// Fold sharded runs (in shard order) into one [`BatchRun`].  Scores,
/// predictions and every profile counter come out identical to a
/// sequential run over the concatenated samples — shard boundaries only
/// change *when* profiles merge, and [`Profile::merge`] folds the same
/// values in the same sample order either way.
fn merge_runs(runs: Vec<Result<BatchRun>>, n_samples: usize) -> Result<BatchRun> {
    let mut scores = Vec::with_capacity(n_samples);
    let mut predictions = Vec::with_capacity(n_samples);
    let mut profile = Profile::default();
    for r in runs {
        let r = r?;
        scores.extend(r.scores);
        predictions.extend(r.predictions);
        profile.merge(&r.profile);
    }
    let cps = profile.cycles as f64 / n_samples.max(1) as f64;
    Ok(BatchRun { scores, predictions, profile, cycles_per_sample: cps })
}

/// [`run_rv32`] with the samples sharded across `pool` (each shard is an
/// independent ISS instance; results gather in sample order).
pub fn run_rv32_on(
    pool: &ThreadPool,
    model: &Model,
    prog: &Rv32Program,
    xs: &[Vec<f32>],
) -> Result<BatchRun> {
    let shards: Vec<&[Vec<f32>]> = xs.chunks(shard_size(xs.len(), pool.threads())).collect();
    let runs = pool.par_map(shards, |shard| run_rv32(model, prog, shard));
    merge_runs(runs, xs.len())
}

/// [`run_tpisa`] with the samples sharded across `pool`.
pub fn run_tpisa_on(
    pool: &ThreadPool,
    model: &Model,
    prog: &TpIsaProgram,
    xs: &[Vec<f32>],
) -> Result<BatchRun> {
    let shards: Vec<&[Vec<f32>]> = xs.chunks(shard_size(xs.len(), pool.threads())).collect();
    let runs = pool.par_map(shards, |shard| run_tpisa(model, prog, shard));
    merge_runs(runs, xs.len())
}

/// Convenience: accuracy of a batch run against labels.
pub fn accuracy(run: &BatchRun, labels: &[i64]) -> f64 {
    let hits = run.predictions.iter().zip(labels).filter(|(p, y)| p == y).count();
    hits as f64 / labels.len().max(1) as f64
}
