//! ISS execution harness: runs a generated program on the matching
//! simulator for a batch of samples, handling input quantisation /
//! packing, score readout, dequantisation and the prediction head.
//!
//! This is the "Modelsim + testbench" analogue of workflow step ④, and
//! the bit-exactness cross-check target for the PJRT path: for every
//! (model, precision) the ISS scores must equal the HLO executable's
//! scores exactly.
//!
//! Per-sample cost model (§Perf iteration 3): each batch reuses **one**
//! simulator built from the program's `Arc`-shared prepared image —
//! [`crate::sim::PreparedRv32`] / [`crate::sim::PreparedTpIsa`] — and
//! [`reset()`](crate::sim::zero_riscy::ZeroRiscy::reset)s it between
//! samples (a memcpy of the initial memory image), so no per-sample
//! program clone, ROM encode, allocation or per-word constant preload
//! remains.  Input preload and score readout go through the bulk
//! `Mem::write_ram`/`read_ram` (`WordMem::write_words`/`read_words`)
//! helpers — one bounds check per transfer instead of one `Result` per
//! byte/word.
//!
//! The `*_traced` variants are generic over a
//! [`TraceMode`](crate::sim::trace::TraceMode):
//! [`FullProfile`](crate::sim::trace::FullProfile) reproduces the
//! complete utilization profile (the bespoke reduction pass needs it),
//! [`CyclesOnly`](crate::sim::trace::CyclesOnly) skips the per-retire
//! histogram / register-bitmask / max-PC work for callers that only
//! consume scores, predictions and cycle counts (the DSE sweeps, the
//! coordinator crosscheck, accuracy runs).  Both modes produce
//! bit-identical scores, predictions and cycle counts —
//! `tests/iss_equivalence.rs` pins this.
//!
//! Since §Perf iteration 4 every batch executes on the *translated*
//! engine ([`ZeroRiscy::run_translated`] / [`TpIsa::run_translated`]):
//! the prepared image carries a basic-block cache with fused
//! superinstructions for the codegen idioms, so the harness dispatches
//! per block instead of per instruction.  Scores, predictions, cycles
//! and full profiles are bit-identical to the interpreted loop —
//! `tests/iss_equivalence.rs` pins that differentially, including on
//! branch-adversarial fuzz programs.
//!
//! Since §Perf iteration 5 the default entry points
//! ([`run_rv32_traced`] / [`run_tpisa_traced`]) execute each shard as a
//! *batch of lanes* on the lockstep engine
//! ([`BatchRv32`](crate::sim::batch::BatchRv32) /
//! [`BatchTpIsa`](crate::sim::batch::BatchTpIsa)): up to [`BATCH_LANES`]
//! samples share one prepared image, each translated block is fetched
//! once and retired lane-parallel, and diverging lanes drain on the
//! scalar path and rejoin.  The pre-batching per-sample loops survive
//! verbatim as [`run_rv32_scalar_traced`] / [`run_tpisa_scalar_traced`]
//! — they are the reference the batched path is differentially pinned
//! against (`tests/iss_batch_equivalence.rs`: bit-identical scores,
//! predictions, cycles, instructions and full profiles per sample).
//!
//! [`run_rv32_on`] / [`run_tpisa_on`] shard a batch across a thread
//! pool (each shard runs as one lane batch); the sharded results
//! merge in sample order, so they are interchangeable with the
//! sequential [`run_rv32`] / [`run_tpisa`].
//!
//! The fault-injection surface rides the same batched engine:
//! [`run_rv32_batched_with_plans`] / [`run_tpisa_batched_with_plans`]
//! arm a per-sample [`FaultPlan`] on each lane (the serving guard's
//! injection door), and [`run_rv32_faulted`] / [`run_tpisa_faulted`]
//! classify per-trial outcomes to [`FaultOutcome`]s for the resilience
//! campaign instead of failing the whole batch on the first fault.

use std::sync::Arc;

use anyhow::{Context, Result};

use super::codegen_rv32::{InputFormat, Rv32Program, INPUT_OFF, SCORES_OFF};
use super::codegen_tpisa::TpIsaProgram;
use super::model::Model;
use super::quant::{pack_vec, quantize};
use crate::sim::batch::{BatchRv32, BatchTpIsa};
use crate::sim::fault::{FaultPlan, FaultState};
use crate::sim::tpisa::TpIsa;
use crate::sim::trace::{CyclesOnly, FullProfile, Profile, TraceMode};
use crate::sim::zero_riscy::{Halt, ZeroRiscy};
use crate::sim::{ExecError, ExecStats, PreparedRv32, PreparedTpIsa};
use crate::util::threadpool::ThreadPool;

/// Default lane count of the batched lockstep engine: wide enough to
/// amortize block fetch/decode across samples, narrow enough that the
/// per-lane RAM images stay cache-resident.
pub const BATCH_LANES: usize = 64;

/// Result of running a batch through an ISS.
#[derive(Debug, Clone)]
pub struct BatchRun {
    /// Uniform score vectors (post-head), one per sample.
    pub scores: Vec<Vec<f64>>,
    pub predictions: Vec<i64>,
    /// Aggregated execution profile (complete under `FullProfile`;
    /// cycles/instructions/event counters only under `CyclesOnly`).
    pub profile: Profile,
    /// Cycles per sample (mean).
    pub cycles_per_sample: f64,
    /// Translated-engine counters summed over the batch (block
    /// dispatches, fused superinstructions, scalar fallbacks) — the
    /// telemetry feed for `coordinator::service`'s ISS counters.
    pub exec_stats: ExecStats,
}

fn empty_run() -> BatchRun {
    BatchRun {
        scores: Vec::new(),
        predictions: Vec::new(),
        profile: Profile::default(),
        cycles_per_sample: 0.0,
        exec_stats: ExecStats::default(),
    }
}

/// Typed mapping from a clean-run halt state to the error contract:
/// running out of fuel is [`ExecError::FuelExhausted`] (so callers
/// match the variant, not a message substring); any other non-`ebreak`
/// stop keeps a descriptive message — no codegen program ever issues
/// `ecall`.
fn check_rv32_halt(halt: Halt) -> Result<()> {
    match halt {
        Halt::Break => Ok(()),
        Halt::Fuel => Err(ExecError::FuelExhausted.into()),
        other => Err(anyhow::anyhow!("program did not halt cleanly: {other:?}")),
    }
}

/// TP-ISA twin of [`check_rv32_halt`].
fn check_tpisa_halt(halt: crate::sim::tpisa::Halt) -> Result<()> {
    match halt {
        crate::sim::tpisa::Halt::Halted => Ok(()),
        crate::sim::tpisa::Halt::Fuel => Err(ExecError::FuelExhausted.into()),
    }
}

/// How one fault-injection trial ended (the resilience campaign's
/// classification input — see `bespoke::resilience`).
#[derive(Debug, Clone)]
pub enum FaultOutcome {
    /// The program halted normally; the (possibly corrupted) post-head
    /// scores.
    Scores(Vec<f64>),
    /// Execution faulted — e.g. a flipped register sent the PC outside
    /// the program image.  Carries the rendered error.
    Crash(String),
    /// The fuel budget ran out: the injected fault livelocked the
    /// program (a corrupted loop counter that never reaches its bound).
    Hang,
}

/// Quantise + lay out one input vector per the program's contract.
/// Public so the perf bench preloads exactly what the harness would —
/// the I/O contract has one definition, not a per-caller copy.
pub fn input_bytes_rv32(model: &Model, prog: &Rv32Program, x: &[f32]) -> Result<Vec<u8>> {
    let p = prog.variant.quant_precision();
    let fx = model.qlayers(p)?[0].fx;
    let qx: Vec<i64> = x.iter().map(|&v| quantize(v as f64, fx, p)).collect();
    let mut bytes = Vec::new();
    match prog.input_format {
        InputFormat::I16 => {
            for q in qx {
                bytes.extend_from_slice(&(q as i16).to_le_bytes());
            }
        }
        InputFormat::Packed(prec) => {
            for w in pack_vec(&qx, prec, 32) {
                bytes.extend_from_slice(&(w as u32).to_le_bytes());
            }
        }
    }
    Ok(bytes)
}

/// Run a batch of samples through the Zero-Riscy ISS with full
/// profiling (the pre-rework behaviour).
pub fn run_rv32(model: &Model, prog: &Rv32Program, xs: &[Vec<f32>]) -> Result<BatchRun> {
    run_rv32_traced::<FullProfile>(model, prog, xs)
}

/// [`run_rv32`] generic over the tracing mode.  Executes on the
/// batched lockstep engine with the default [`BATCH_LANES`] width.
pub fn run_rv32_traced<M: TraceMode>(
    model: &Model,
    prog: &Rv32Program,
    xs: &[Vec<f32>],
) -> Result<BatchRun> {
    run_rv32_batched::<M>(model, prog, xs, BATCH_LANES)
}

/// One sample per lane on [`BatchRv32`], chunking `xs` by `lanes`.
/// Public (with an explicit lane count) so the differential suite can
/// sweep batch widths; scores, predictions, cycles and profiles are
/// bit-identical to [`run_rv32_scalar_traced`] per sample.
pub fn run_rv32_batched<M: TraceMode>(
    model: &Model,
    prog: &Rv32Program,
    xs: &[Vec<f32>],
    lanes: usize,
) -> Result<BatchRun> {
    run_rv32_batched_with_plans::<M>(model, prog, xs, lanes, &[])
}

/// [`run_rv32_batched`] with a per-sample [`FaultPlan`] armed on each
/// lane before it executes: `plans[i]` rides sample `i`; an empty (or
/// short) slice leaves the remaining lanes fault-free, and empty /
/// zero-rate plans are bit-identical to the plain entry point
/// (`tests/fault_identity.rs` pins that).  This is the injection door
/// the serving guard (`coordinator::service`) uses to corrupt its own
/// MAC results under test.
pub fn run_rv32_batched_with_plans<M: TraceMode>(
    model: &Model,
    prog: &Rv32Program,
    xs: &[Vec<f32>],
    lanes: usize,
    plans: &[FaultPlan],
) -> Result<BatchRun> {
    if xs.is_empty() {
        return Ok(empty_run());
    }
    let lanes = lanes.clamp(1, xs.len());
    let mut scores = Vec::with_capacity(xs.len());
    let mut predictions = Vec::with_capacity(xs.len());
    let mut batch = BatchRv32::new(Arc::clone(&prog.prepared), lanes);
    for (ci, chunk) in xs.chunks(lanes).enumerate() {
        if ci > 0 {
            batch.reset();
        }
        for (i, x) in chunk.iter().enumerate() {
            let input = input_bytes_rv32(model, prog, x)?;
            batch.lane_mut(i).mem.write_ram(INPUT_OFF as usize, &input)?;
            batch.lane_mut(i).fault =
                plans.get(ci * lanes + i).and_then(|p| FaultState::armed(p.clone()));
        }
        let results = batch.run::<M>(chunk.len(), 50_000_000);
        // Readout scans lanes in sample order, so the first failing
        // sample surfaces the same error a scalar sweep would.
        for (i, res) in results.into_iter().enumerate() {
            let halt = res.context("ISS run")?;
            check_rv32_halt(halt)?;
            let mut raw = Vec::with_capacity(prog.n_scores);
            {
                let bytes = batch.lane(i).mem.read_ram(SCORES_OFF as usize, 4 * prog.n_scores)?;
                for j in 0..prog.n_scores {
                    let b = &bytes[4 * j..4 * j + 4];
                    let acc = i32::from_le_bytes([b[0], b[1], b[2], b[3]]) as i64;
                    raw.push(acc as f64 / prog.score_scale);
                }
            }
            let s = model.head_scores(&raw);
            predictions.push(model.predict(&s));
            scores.push(s);
        }
    }
    let mut profile = Profile::default();
    batch.fold_profile(&mut profile);
    let exec_stats = batch.exec_stats();
    let cps = profile.cycles as f64 / xs.len() as f64;
    Ok(BatchRun { scores, predictions, profile, cycles_per_sample: cps, exec_stats })
}

/// The pre-batching per-sample loop: one reused scalar simulator, one
/// `run_translated` per sample.  This is the reference semantics the
/// batched path is pinned against (`tests/iss_batch_equivalence.rs`)
/// and the per-sample-latency row of the perf bench.
pub fn run_rv32_scalar_traced<M: TraceMode>(
    model: &Model,
    prog: &Rv32Program,
    xs: &[Vec<f32>],
) -> Result<BatchRun> {
    if xs.is_empty() {
        return Ok(empty_run());
    }
    let mut scores = Vec::with_capacity(xs.len());
    let mut predictions = Vec::with_capacity(xs.len());
    let mut sim = ZeroRiscy::from_prepared(Arc::clone(&prog.prepared));
    for (si, x) in xs.iter().enumerate() {
        if si > 0 {
            sim.reset();
        }
        let input = input_bytes_rv32(model, prog, x)?;
        sim.mem.write_ram(INPUT_OFF as usize, &input)?;
        let halt = sim.run_translated::<M>(50_000_000).context("ISS run")?;
        check_rv32_halt(halt)?;
        let mut raw = Vec::with_capacity(prog.n_scores);
        {
            let bytes = sim.mem.read_ram(SCORES_OFF as usize, 4 * prog.n_scores)?;
            for j in 0..prog.n_scores {
                let b = &bytes[4 * j..4 * j + 4];
                let acc = i32::from_le_bytes([b[0], b[1], b[2], b[3]]) as i64;
                raw.push(acc as f64 / prog.score_scale);
            }
        }
        let s = model.head_scores(&raw);
        predictions.push(model.predict(&s));
        scores.push(s);
    }
    // One reused simulator accumulates the whole batch's profile — the
    // same totals as merging per-sample profiles in sample order.
    let exec_stats = sim.exec_stats;
    let profile = sim.profile;
    let cps = profile.cycles as f64 / xs.len() as f64;
    Ok(BatchRun { scores, predictions, profile, cycles_per_sample: cps, exec_stats })
}

/// Quantise + pack one input vector per the TP-ISA program's contract.
/// Public for the same reason as [`input_bytes_rv32`].
pub fn input_words_tpisa(model: &Model, prog: &TpIsaProgram, x: &[f32]) -> Result<Vec<u64>> {
    let p = prog.quant_precision;
    let fx = model.qlayers(p)?[0].fx;
    let qx: Vec<i64> = x.iter().map(|&v| quantize(v as f64, fx, p)).collect();
    Ok(if prog.packed_input {
        pack_vec(&qx, p, prog.datapath)
    } else {
        qx.iter().map(|&q| q as u64).collect()
    })
}

/// Run a batch through the TP-ISA ISS with full profiling.
pub fn run_tpisa(model: &Model, prog: &TpIsaProgram, xs: &[Vec<f32>]) -> Result<BatchRun> {
    run_tpisa_traced::<FullProfile>(model, prog, xs)
}

/// [`run_tpisa`] generic over the tracing mode.  Executes on the
/// batched lockstep engine with the default [`BATCH_LANES`] width.
pub fn run_tpisa_traced<M: TraceMode>(
    model: &Model,
    prog: &TpIsaProgram,
    xs: &[Vec<f32>],
) -> Result<BatchRun> {
    run_tpisa_batched::<M>(model, prog, xs, BATCH_LANES)
}

/// One sample per lane on [`BatchTpIsa`], chunking `xs` by `lanes`;
/// the TP-ISA twin of [`run_rv32_batched`].
pub fn run_tpisa_batched<M: TraceMode>(
    model: &Model,
    prog: &TpIsaProgram,
    xs: &[Vec<f32>],
    lanes: usize,
) -> Result<BatchRun> {
    run_tpisa_batched_with_plans::<M>(model, prog, xs, lanes, &[])
}

/// TP-ISA twin of [`run_rv32_batched_with_plans`]: `plans[i]` is armed
/// on sample `i`'s lane; empty / zero-rate plans are bit-identical to
/// [`run_tpisa_batched`].
pub fn run_tpisa_batched_with_plans<M: TraceMode>(
    model: &Model,
    prog: &TpIsaProgram,
    xs: &[Vec<f32>],
    lanes: usize,
    plans: &[FaultPlan],
) -> Result<BatchRun> {
    if xs.is_empty() {
        return Ok(empty_run());
    }
    let lanes = lanes.clamp(1, xs.len());
    let nacc = (32 / prog.datapath).max(1) as usize;
    let mut scores = Vec::with_capacity(xs.len());
    let mut predictions = Vec::with_capacity(xs.len());
    let mut batch = BatchTpIsa::new(Arc::clone(&prog.prepared), lanes);
    for (ci, chunk) in xs.chunks(lanes).enumerate() {
        if ci > 0 {
            // Memcpy-restores the constants the prepared image carries.
            batch.reset();
        }
        for (i, x) in chunk.iter().enumerate() {
            let words = input_words_tpisa(model, prog, x)?;
            batch.lane_mut(i).dmem.write_words(prog.input_base, &words)?;
            batch.lane_mut(i).fault =
                plans.get(ci * lanes + i).and_then(|p| FaultState::armed(p.clone()));
        }
        let results = batch.run::<M>(chunk.len(), 500_000_000);
        for (i, res) in results.into_iter().enumerate() {
            let halt = res.context("TP-ISA run")?;
            check_tpisa_halt(halt)?;
            // Scores: nacc d-bit chunks per output, little-endian.
            let mut raw = Vec::with_capacity(prog.n_scores);
            {
                let chunks = batch.lane(i).dmem.read_words(prog.score_base, prog.n_scores * nacc)?;
                for j in 0..prog.n_scores {
                    let mut acc: u64 = 0;
                    for (wi, &chunk) in chunks[j * nacc..(j + 1) * nacc].iter().enumerate() {
                        acc |= chunk << (prog.datapath * wi as u32);
                    }
                    let acc = crate::sim::mac_model::sext(acc, 32);
                    raw.push(acc as f64 / prog.score_scale);
                }
            }
            let s = model.head_scores(&raw);
            predictions.push(model.predict(&s));
            scores.push(s);
        }
    }
    let mut profile = Profile::default();
    batch.fold_profile(&mut profile);
    let exec_stats = batch.exec_stats();
    let cps = profile.cycles as f64 / xs.len() as f64;
    Ok(BatchRun { scores, predictions, profile, cycles_per_sample: cps, exec_stats })
}

/// The pre-batching per-sample TP-ISA loop — the scalar reference the
/// batched path is pinned against.
pub fn run_tpisa_scalar_traced<M: TraceMode>(
    model: &Model,
    prog: &TpIsaProgram,
    xs: &[Vec<f32>],
) -> Result<BatchRun> {
    if xs.is_empty() {
        return Ok(empty_run());
    }
    let nacc = (32 / prog.datapath).max(1) as usize;
    let mut scores = Vec::with_capacity(xs.len());
    let mut predictions = Vec::with_capacity(xs.len());
    let mut sim = TpIsa::from_prepared(Arc::clone(&prog.prepared));
    for (si, x) in xs.iter().enumerate() {
        if si > 0 {
            // Memcpy-restores the constants the prepared image carries.
            sim.reset();
        }
        let words = input_words_tpisa(model, prog, x)?;
        sim.dmem.write_words(prog.input_base, &words)?;
        let halt = sim.run_translated::<M>(500_000_000).context("TP-ISA run")?;
        check_tpisa_halt(halt)?;
        // Scores: nacc d-bit chunks per output, little-endian.
        let mut raw = Vec::with_capacity(prog.n_scores);
        {
            let chunks = sim.dmem.read_words(prog.score_base, prog.n_scores * nacc)?;
            for j in 0..prog.n_scores {
                let mut acc: u64 = 0;
                for (wi, &chunk) in chunks[j * nacc..(j + 1) * nacc].iter().enumerate() {
                    acc |= chunk << (prog.datapath * wi as u32);
                }
                let acc = crate::sim::mac_model::sext(acc, 32);
                raw.push(acc as f64 / prog.score_scale);
            }
        }
        let s = model.head_scores(&raw);
        predictions.push(model.predict(&s));
        scores.push(s);
    }
    let exec_stats = sim.exec_stats;
    let profile = sim.profile;
    let cps = profile.cycles as f64 / xs.len() as f64;
    Ok(BatchRun { scores, predictions, profile, cycles_per_sample: cps, exec_stats })
}

/// One fault-injection trial per lane: sample `xs[i]` runs under
/// `plans[i]` on `prepared` (normally `prog.prepared`; the stuck-at ROM
/// sweep passes a patched image from
/// [`crate::sim::fault::rv32_with_stuck_rom`]).  Unlike the clean
/// runners, per-lane failures are *data*, not errors: every trial
/// classifies to a [`FaultOutcome`], and `Err` is reserved for harness
/// bugs (bad input layout).  `fuel` is caller-set so campaigns can
/// tighten the hang horizon below the production 50M budget.
pub fn run_rv32_faulted(
    model: &Model,
    prog: &Rv32Program,
    prepared: &Arc<PreparedRv32>,
    xs: &[Vec<f32>],
    plans: &[FaultPlan],
    lanes: usize,
    fuel: u64,
) -> Result<Vec<FaultOutcome>> {
    if xs.is_empty() {
        return Ok(Vec::new());
    }
    let lanes = lanes.clamp(1, xs.len());
    let mut out = Vec::with_capacity(xs.len());
    let mut batch = BatchRv32::new(Arc::clone(prepared), lanes);
    for (ci, chunk) in xs.chunks(lanes).enumerate() {
        if ci > 0 {
            batch.reset();
        }
        for (i, x) in chunk.iter().enumerate() {
            let input = input_bytes_rv32(model, prog, x)?;
            batch.lane_mut(i).mem.write_ram(INPUT_OFF as usize, &input)?;
            batch.lane_mut(i).fault =
                plans.get(ci * lanes + i).and_then(|p| FaultState::armed(p.clone()));
        }
        let results = batch.run::<CyclesOnly>(chunk.len(), fuel);
        for (i, res) in results.into_iter().enumerate() {
            out.push(match res {
                Ok(Halt::Break) => {
                    let mut raw = Vec::with_capacity(prog.n_scores);
                    let bytes =
                        batch.lane(i).mem.read_ram(SCORES_OFF as usize, 4 * prog.n_scores)?;
                    for j in 0..prog.n_scores {
                        let b = &bytes[4 * j..4 * j + 4];
                        let acc = i32::from_le_bytes([b[0], b[1], b[2], b[3]]) as i64;
                        raw.push(acc as f64 / prog.score_scale);
                    }
                    FaultOutcome::Scores(model.head_scores(&raw))
                }
                Ok(Halt::Fuel) => FaultOutcome::Hang,
                Ok(other) => FaultOutcome::Crash(format!("stopped on {other:?}, not ebreak")),
                Err(e) => FaultOutcome::Crash(format!("{e:#}")),
            });
        }
    }
    Ok(out)
}

/// TP-ISA twin of [`run_rv32_faulted`] (patched images come from
/// [`crate::sim::fault::tpisa_with_stuck_dmem`]).
pub fn run_tpisa_faulted(
    model: &Model,
    prog: &TpIsaProgram,
    prepared: &Arc<PreparedTpIsa>,
    xs: &[Vec<f32>],
    plans: &[FaultPlan],
    lanes: usize,
    fuel: u64,
) -> Result<Vec<FaultOutcome>> {
    if xs.is_empty() {
        return Ok(Vec::new());
    }
    let lanes = lanes.clamp(1, xs.len());
    let nacc = (32 / prog.datapath).max(1) as usize;
    let mut out = Vec::with_capacity(xs.len());
    let mut batch = BatchTpIsa::new(Arc::clone(prepared), lanes);
    for (ci, chunk) in xs.chunks(lanes).enumerate() {
        if ci > 0 {
            batch.reset();
        }
        for (i, x) in chunk.iter().enumerate() {
            let words = input_words_tpisa(model, prog, x)?;
            batch.lane_mut(i).dmem.write_words(prog.input_base, &words)?;
            batch.lane_mut(i).fault =
                plans.get(ci * lanes + i).and_then(|p| FaultState::armed(p.clone()));
        }
        let results = batch.run::<CyclesOnly>(chunk.len(), fuel);
        for (i, res) in results.into_iter().enumerate() {
            out.push(match res {
                Ok(crate::sim::tpisa::Halt::Halted) => {
                    let mut raw = Vec::with_capacity(prog.n_scores);
                    let chunks =
                        batch.lane(i).dmem.read_words(prog.score_base, prog.n_scores * nacc)?;
                    for j in 0..prog.n_scores {
                        let mut acc: u64 = 0;
                        for (wi, &chunk) in chunks[j * nacc..(j + 1) * nacc].iter().enumerate() {
                            acc |= chunk << (prog.datapath * wi as u32);
                        }
                        let acc = crate::sim::mac_model::sext(acc, 32);
                        raw.push(acc as f64 / prog.score_scale);
                    }
                    FaultOutcome::Scores(model.head_scores(&raw))
                }
                Ok(crate::sim::tpisa::Halt::Fuel) => FaultOutcome::Hang,
                Err(e) => FaultOutcome::Crash(format!("{e:#}")),
            });
        }
    }
    Ok(out)
}

/// Shard size for parallel batch runs: oversubscribe the pool 4x so
/// uneven per-sample cost (ReLU/branch paths) load-balances.
fn shard_size(n_samples: usize, threads: usize) -> usize {
    n_samples.div_ceil(threads.max(1) * 4).max(1)
}

/// Fold sharded runs (in shard order) into one [`BatchRun`].  Scores,
/// predictions and every profile counter come out identical to a
/// sequential run over the concatenated samples — shard boundaries only
/// change *when* profiles merge, and [`Profile::merge`] folds the same
/// values in the same sample order either way.
fn merge_runs(runs: Vec<Result<BatchRun>>, n_samples: usize) -> Result<BatchRun> {
    let mut scores = Vec::with_capacity(n_samples);
    let mut predictions = Vec::with_capacity(n_samples);
    let mut profile = Profile::default();
    let mut exec_stats = ExecStats::default();
    for r in runs {
        let r = r?;
        scores.extend(r.scores);
        predictions.extend(r.predictions);
        profile.merge(&r.profile);
        exec_stats.merge(&r.exec_stats);
    }
    let cps = profile.cycles as f64 / n_samples.max(1) as f64;
    Ok(BatchRun { scores, predictions, profile, cycles_per_sample: cps, exec_stats })
}

/// [`run_rv32`] with the samples sharded across `pool` (each shard
/// reuses one ISS instance; results gather in sample order).
pub fn run_rv32_on(
    pool: &ThreadPool,
    model: &Model,
    prog: &Rv32Program,
    xs: &[Vec<f32>],
) -> Result<BatchRun> {
    run_rv32_on_traced::<FullProfile>(pool, model, prog, xs)
}

/// [`run_rv32_on`] generic over the tracing mode.
pub fn run_rv32_on_traced<M: TraceMode>(
    pool: &ThreadPool,
    model: &Model,
    prog: &Rv32Program,
    xs: &[Vec<f32>],
) -> Result<BatchRun> {
    let shards: Vec<&[Vec<f32>]> = xs.chunks(shard_size(xs.len(), pool.threads())).collect();
    let runs = pool.par_map(shards, |shard| run_rv32_traced::<M>(model, prog, shard));
    merge_runs(runs, xs.len())
}

/// [`run_tpisa`] with the samples sharded across `pool`.
pub fn run_tpisa_on(
    pool: &ThreadPool,
    model: &Model,
    prog: &TpIsaProgram,
    xs: &[Vec<f32>],
) -> Result<BatchRun> {
    run_tpisa_on_traced::<FullProfile>(pool, model, prog, xs)
}

/// [`run_tpisa_on`] generic over the tracing mode.
pub fn run_tpisa_on_traced<M: TraceMode>(
    pool: &ThreadPool,
    model: &Model,
    prog: &TpIsaProgram,
    xs: &[Vec<f32>],
) -> Result<BatchRun> {
    let shards: Vec<&[Vec<f32>]> = xs.chunks(shard_size(xs.len(), pool.threads())).collect();
    let runs = pool.par_map(shards, |shard| run_tpisa_traced::<M>(model, prog, shard));
    merge_runs(runs, xs.len())
}

/// Convenience: accuracy of a batch run against labels.
pub fn accuracy(run: &BatchRun, labels: &[i64]) -> f64 {
    let hits = run.predictions.iter().zip(labels).filter(|(p, y)| p == y).count();
    hits as f64 / labels.len().max(1) as f64
}
