//! ML model handling on the rust side: weights/manifest loading, the
//! fixed-point contract (mirroring `python/compile/quant.py`), dataset
//! loading, hermetic artifact fixtures ([`fixtures`]), code generation
//! for both cores, the ISS execution harness, and the §III-A profiling
//! suite.

pub mod codegen_rv32;
pub mod codegen_tpisa;
pub mod dataset;
pub mod fixtures;
pub mod harness;
pub mod manifest;
pub mod microbench;
pub mod model;
pub mod quant;
