//! Serving-layer bench: end-to-end HTTP frontend throughput and
//! latency under a closed-loop device fleet at sizes {1, 8, 64}
//! (ISSUE 3 acceptance artifact).  Each fleet size gets a fresh
//! service + frontend on an ephemeral port; the load generator reports
//! requests/s and nearest-rank p50/p90/p99 over real sockets, and the
//! coordinator line shows how well concurrent connections coalesced in
//! the dynamic batcher (mean-batch > 1 at fleet >= 8).

use std::sync::Arc;

use printed_bespoke::coordinator::service::{Service, ServiceConfig};
use printed_bespoke::server::{loadgen::LoadgenConfig, Server, ServerConfig};

fn main() -> anyhow::Result<()> {
    // (fleet, requests per device): ~256-512 total requests per point.
    for &(fleet, per_device) in &[(1usize, 256usize), (8, 64), (64, 8)] {
        let svc = Arc::new(Service::start(ServiceConfig::default())?);
        // +4 headroom: the warm-up run's connection may not have been
        // reaped yet when the timed fleet connects (the acceptor
        // refuses over-capacity connections with 503).
        let scfg = ServerConfig { http_threads: fleet.max(8) + 4, ..ServerConfig::default() };
        let mut server = Server::start(Arc::clone(&svc), scfg)?;

        // Warm-up: compile every (model, p8) executable once so the
        // timed run measures serving, not compilation.
        let warm =
            LoadgenConfig { fleet: 1, requests_per_device: 16, seed: 99, ..Default::default() };
        printed_bespoke::server::loadgen::run(server.addr(), &warm)?;

        let cfg = LoadgenConfig {
            fleet,
            requests_per_device: per_device,
            seed: 42,
            think_ms: 0,
            precision: 8,
        };
        let r = printed_bespoke::server::loadgen::run(server.addr(), &cfg)?;
        println!(
            "fleet {fleet:>3} x {per_device:>3} reqs: {:>8.0} req/s  p50 {:>7.2} ms  \
             p90 {:>7.2} ms  p99 {:>7.2} ms  errors {}",
            r.rps, r.p50_ms, r.p90_ms, r.p99_ms, r.errors
        );
        server.shutdown();
        println!("  coordinator: {}", svc.metrics.lock().unwrap().summary());
        assert_eq!(r.errors, 0, "serving errors under fleet {fleet}");
        assert!(r.rps > 0.0, "zero throughput under fleet {fleet}");
    }
    Ok(())
}
