//! Serving-layer bench: end-to-end HTTP frontend throughput and
//! latency under device fleets at sizes {64, 1k, 10k} (ISSUE 7
//! acceptance artifact — fleet-scale serving on the event-driven
//! reactor).  Each fleet size gets a fresh service + frontend on an
//! ephemeral port with a deliberately small, *fixed* compute pool
//! (`http_threads = 8`): connection concurrency is bounded by
//! `max_connections`, not the pool, so 10k mostly-idle keep-alive
//! devices ride one reactor thread.  The load generator reports
//! requests/s and nearest-rank p50/p90/p99 over real sockets, and the
//! coordinator line shows how well concurrent connections coalesced in
//! the dynamic batcher (mean-batch > 1 at fleet >= 64).
//!
//! The 10k point needs ~2 fds per device in one process (server side +
//! client side); the fd limit is raised best-effort and the point is
//! skipped with a note if the OS won't allow it.

use std::sync::Arc;

use printed_bespoke::coordinator::service::{Service, ServiceConfig};
use printed_bespoke::server::{loadgen::LoadgenConfig, Server, ServerConfig};
use printed_bespoke::util::poll::raise_nofile_limit;

/// Compute pool size, fixed across all fleet sizes on purpose: the old
/// thread-per-connection model would cap concurrency here.
const HTTP_THREADS: usize = 8;

fn main() -> anyhow::Result<()> {
    // (fleet, requests per device): bounded total request counts.
    for &(fleet, per_device) in &[(64usize, 16usize), (1_000, 4), (10_000, 1)] {
        let need_fds = fleet as u64 * 2 + 512;
        let have_fds = raise_nofile_limit(need_fds);
        if have_fds < need_fds {
            println!(
                "fleet {fleet:>5}: SKIPPED (need ~{need_fds} fds, limit {have_fds} — raise \
                 ulimit -n)"
            );
            continue;
        }
        let svc = Arc::new(Service::start(ServiceConfig::default())?);
        let scfg = ServerConfig {
            http_threads: HTTP_THREADS,
            // Admission headroom over the fleet (warm-up + reconnects).
            max_connections: fleet + 64,
            max_queued: 4_096,
            // Long keep-alive: an idle device parked between requests
            // must not be reaped mid-bench.
            keep_alive_ms: 60_000,
            ..ServerConfig::default()
        };
        let mut server = Server::start(Arc::clone(&svc), scfg)?;

        // Warm-up: compile every (model, p8) executable once so the
        // timed run measures serving, not compilation.
        let warm =
            LoadgenConfig { fleet: 1, requests_per_device: 16, seed: 99, ..Default::default() };
        printed_bespoke::server::loadgen::run(server.addr(), &warm)?;

        let cfg = LoadgenConfig {
            fleet,
            requests_per_device: per_device,
            seed: 42,
            think_ms: 0,
            precision: 8,
            ..Default::default()
        };
        let r = printed_bespoke::server::loadgen::run(server.addr(), &cfg)?;
        println!(
            "fleet {fleet:>5} x {per_device:>3} reqs ({HTTP_THREADS} compute threads): \
             {:>8.0} req/s  p50 {:>7.2} ms  p90 {:>7.2} ms  p99 {:>7.2} ms  errors {}",
            r.rps, r.p50_ms, r.p90_ms, r.p99_ms, r.errors
        );
        let m = &server.metrics;
        let admitted = m.connections.load(std::sync::atomic::Ordering::Relaxed);
        let refused = m.rejected_busy.load(std::sync::atomic::Ordering::Relaxed);
        server.shutdown();
        println!("  coordinator: {}", svc.metrics.lock().unwrap().summary());
        assert_eq!(r.errors, 0, "serving errors under fleet {fleet}");
        assert!(r.rps > 0.0, "zero throughput under fleet {fleet}");
        // Every device held a keep-alive connection concurrently on an
        // 8-thread compute pool: connection concurrency is bounded by
        // max_connections, not http_threads (the old model would have
        // refused everything past the pool with 503).
        assert!(admitted as usize > fleet, "fleet {fleet}: only {admitted} admitted");
        assert_eq!(refused, 0, "fleet {fleet}: {refused} connections refused at admission");
    }
    Ok(())
}
