//! Bench: regenerate paper Fig. 1 (baseline area / power / clock for
//! Zero-Riscy and TP-ISA in EGFET, plus the ZR unit breakdown), and
//! time the synthesis pass itself.

use printed_bespoke::dse::context::EvalContext;
use printed_bespoke::dse::report;
use printed_bespoke::hw::egfet::egfet;
use printed_bespoke::hw::synth::{synthesize, zero_riscy};
use printed_bespoke::util::bench::bench;

fn main() -> anyhow::Result<()> {
    let ctx = EvalContext::load(4)?;
    let f = report::fig1(&ctx);
    println!("{}", f.text);

    // Sanity pins (the calibration anchors).
    assert!((f.zr.area_cm2() - 67.53).abs() / 67.53 < 0.005);
    assert!((f.zr.power_mw - 291.21).abs() / 291.21 < 0.005);
    assert!(f.tp4.area_mm2 < f.tp32.area_mm2);

    let tech = egfet();
    let spec = zero_riscy();
    bench("synthesize(zero-riscy)", 10, 100, || {
        std::hint::black_box(synthesize(&spec, &tech));
    });
    Ok(())
}
