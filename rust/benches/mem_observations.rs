//! Bench: regenerate the paper's §IV-B printed-memory observations:
//! (a) narrower bitwidths use fewer ROM cells, (b) hardware multiply
//! saves ROM vs ALU-scheduled multiplication, (c) SIMD saves extra ROM
//! by removing loop control.

use printed_bespoke::dse::context::EvalContext;
use printed_bespoke::dse::report;

fn main() -> anyhow::Result<()> {
    let ctx = EvalContext::load(4)?;
    let m = report::mem(&ctx)?;
    println!("{}", m.text);

    // (b): positive saving from the hardware multiplier (paper: 11.1%).
    assert!(m.mul_saving_pct > 3.0, "mul saving {}", m.mul_saving_pct);
    // (c): positive extra saving from single-pass SIMD (paper: 1-2%).
    assert!(m.simd_saving_pct > 0.0, "simd saving {}", m.simd_saving_pct);
    // (a): among TP-ISA baselines, the 4-bit ROM is not the largest and
    // the per-width MAC variant always beats its own baseline.
    let cells = |label: &str| m.tp_rom.iter().find(|(l, _)| l == label).unwrap().1;
    assert!(cells("d8m") < cells("d8"));
    assert!(cells("d16m") < cells("d16"));
    assert!(cells("d32m") < cells("d32"));
    println!("§IV-B observations: OK");
    Ok(())
}
