//! Perf bench (L3 hot path): ISS simulation rate in instructions/second
//! (MIPS) per (core, model, variant), in four configurations:
//!
//! * `legacy`     — the pre-rework per-sample cost model (fresh
//!   simulator per sample, RAM/dmem realloc, per-byte/word preloads,
//!   full profiling) — the PR 4 *before* number.  Simulators come from
//!   the shared prepared image so legacy is not charged for block
//!   translation (work the old code never did); the omitted per-sample
//!   ROM re-encode makes this an upper bound on the old path's MIPS;
//! * `interp`     — reused simulator + prepared image, per-instruction
//!   `run_traced::<CyclesOnly>` — the PR 4 *after* number and the
//!   baseline the translated engine is gated against (≥2× on the
//!   straight-line-dominant MLP/SVM models);
//! * `full`       — block-translated `run_translated::<FullProfile>`,
//!   one sample at a time (`run_rv32_scalar_traced`);
//! * `translated` — block-translated `run_translated::<CyclesOnly>`,
//!   one sample at a time — the PR 5 *before* number and the
//!   configuration the translated-vs-interpreted gate ratios against;
//! * `batched`    — the batched lockstep engine (`sim::batch` via
//!   `run_rv32_batched` / `run_tpisa_batched`, one sample per lane,
//!   `BATCH` lanes): the path every production consumer (harness, DSE
//!   sweeps, crosscheck, serving) takes since §Perf iteration 5,
//!   measured in both trace modes.
//!
//! Also reports the per-model block-cache statistics: translated
//! blocks, fused superinstructions, static coverage, the dynamic
//! fallback rate (fraction of retired instructions that took the
//! per-instruction fallback), and the batched engine's divergence rate
//! (fallback share of retired instructions across all lanes — lanes
//! that leave lockstep drain on the scalar path).
//!
//! Emits `BENCH_iss.json`; CI diffs it against the committed
//! `BENCH_iss.baseline.json` via `tools/bench_diff.py`, failing on a
//! >20% regression of the translated-vs-interpreted speedup.

use std::sync::Arc;

use printed_bespoke::dse::context::EvalContext;
use printed_bespoke::ml::codegen_rv32::{self, Rv32Program, Rv32Variant, INPUT_OFF, SCORES_OFF};
use printed_bespoke::ml::codegen_tpisa::{self, TpIsaProgram, TpVariant};
use printed_bespoke::ml::harness;
use printed_bespoke::ml::model::Model;
use printed_bespoke::sim::mem::RAM_BASE;
use printed_bespoke::sim::tpisa::TpIsa;
use printed_bespoke::sim::trace::{CyclesOnly, FullProfile, Profile};
use printed_bespoke::sim::zero_riscy::{Halt, ZeroRiscy};
use printed_bespoke::sim::{BatchRv32, BatchTpIsa, ExecStats};
use printed_bespoke::util::bench::bench;

/// Lanes per batched dispatch — matches `harness::BATCH_LANES` clamped
/// to the 32-sample bench set, i.e. one lane per sample.
const BATCH: usize = 32;

struct Row {
    core: &'static str,
    model: String,
    variant: String,
    samples: usize,
    mips_legacy: f64,
    mips_interp: f64,
    mips_full: f64,
    mips_translated: f64,
    mips_batched_full: f64,
    mips_batched_cycles_only: f64,
    batch_size: usize,
    blocks: usize,
    fused: usize,
    static_coverage: f64,
    fallback_rate: f64,
    divergence_rate: f64,
}

/// The pre-rework RV32 harness cost model: fresh simulator + per-byte
/// preload + full profiling per sample.  Built from the shared prepared
/// image so the timing does **not** charge the legacy path for block
/// translation (work the true pre-rework code never did); it omits the
/// legacy per-sample ROM re-encode, so `mips_legacy` is, if anything,
/// a flattering *upper* bound on the old path.  Returns retired
/// instructions (the MIPS denominator).
fn legacy_rv32(model: &Model, prog: &Rv32Program, xs: &[Vec<f32>]) -> u64 {
    let mut instrs = 0u64;
    for x in xs {
        let mut sim = ZeroRiscy::from_prepared(Arc::clone(&prog.prepared));
        let input = harness::input_bytes_rv32(model, prog, x).unwrap();
        for (i, b) in input.iter().enumerate() {
            sim.mem.store_u8(RAM_BASE + INPUT_OFF as u32 + i as u32, *b).unwrap();
        }
        assert_eq!(sim.run(50_000_000).unwrap(), Halt::Break);
        let mut raw = Vec::with_capacity(prog.n_scores);
        for j in 0..prog.n_scores {
            let addr = RAM_BASE + SCORES_OFF as u32 + 4 * j as u32;
            let acc = sim.mem.load_u32(addr).unwrap() as i32 as i64;
            raw.push(acc as f64 / prog.score_scale);
        }
        let s = model.head_scores(&raw);
        std::hint::black_box(model.predict(&s));
        instrs += sim.profile.instructions;
    }
    instrs
}

/// The PR 4 hot path: one reused simulator, bulk preload/readout,
/// per-instruction `run_traced::<CyclesOnly>`.
fn interp_rv32(model: &Model, prog: &Rv32Program, xs: &[Vec<f32>]) -> u64 {
    let mut sim = ZeroRiscy::from_prepared(Arc::clone(&prog.prepared));
    for (si, x) in xs.iter().enumerate() {
        if si > 0 {
            sim.reset();
        }
        let input = harness::input_bytes_rv32(model, prog, x).unwrap();
        sim.mem.write_ram(INPUT_OFF as usize, &input).unwrap();
        assert_eq!(sim.run_traced::<CyclesOnly>(50_000_000).unwrap(), Halt::Break);
        let bytes = sim.mem.read_ram(SCORES_OFF as usize, 4 * prog.n_scores).unwrap();
        std::hint::black_box(bytes[0]);
    }
    sim.profile.instructions
}

/// One translated batch on a local simulator, to harvest the dynamic
/// block/fallback counters the harness does not expose.
fn translated_stats_rv32(model: &Model, prog: &Rv32Program, xs: &[Vec<f32>]) -> (ExecStats, u64) {
    let mut sim = ZeroRiscy::from_prepared(Arc::clone(&prog.prepared));
    for (si, x) in xs.iter().enumerate() {
        if si > 0 {
            sim.reset();
        }
        let input = harness::input_bytes_rv32(model, prog, x).unwrap();
        sim.mem.write_ram(INPUT_OFF as usize, &input).unwrap();
        assert_eq!(sim.run_translated::<CyclesOnly>(50_000_000).unwrap(), Halt::Break);
    }
    (sim.exec_stats, sim.profile.instructions)
}

/// One batched lockstep pass (one lane per sample), to harvest the
/// divergence counters — the fallback share across all lanes, i.e. the
/// fraction of retired instructions that left lockstep and drained on
/// the scalar path.
fn batched_stats_rv32(model: &Model, prog: &Rv32Program, xs: &[Vec<f32>]) -> (ExecStats, u64) {
    let mut batch = BatchRv32::new(Arc::clone(&prog.prepared), xs.len());
    for (i, x) in xs.iter().enumerate() {
        let input = harness::input_bytes_rv32(model, prog, x).unwrap();
        batch.lane_mut(i).mem.write_ram(INPUT_OFF as usize, &input).unwrap();
    }
    for res in batch.run::<CyclesOnly>(xs.len(), 50_000_000) {
        assert_eq!(res.unwrap(), Halt::Break);
    }
    let mut p = Profile::default();
    batch.fold_profile(&mut p);
    (batch.exec_stats(), p.instructions)
}

/// The pre-rework TP-ISA harness cost model: fresh simulator +
/// per-word constant and input preload + full profiling per sample,
/// built from the shared prepared image (no block-translation charge —
/// see [`legacy_rv32`]; the per-word constant re-store keeps the legacy
/// preload cost in the loop).
fn legacy_tpisa(model: &Model, prog: &TpIsaProgram, xs: &[Vec<f32>]) -> u64 {
    let mut instrs = 0u64;
    for x in xs {
        let mut sim = TpIsa::from_prepared(Arc::clone(&prog.prepared));
        for (addr, v) in prog.dmem_image.iter().enumerate() {
            sim.dmem.store(addr as i64, *v).unwrap();
        }
        let words = harness::input_words_tpisa(model, prog, x).unwrap();
        for (i, w) in words.iter().enumerate() {
            sim.dmem.store(prog.input_base as i64 + i as i64, *w).unwrap();
        }
        let halt = sim.run(500_000_000).unwrap();
        assert_eq!(halt, printed_bespoke::sim::tpisa::Halt::Halted);
        let nacc = (32 / prog.datapath).max(1) as usize;
        let chunk = sim.dmem.load(prog.score_base as i64).unwrap();
        std::hint::black_box((chunk, nacc));
        instrs += sim.profile.instructions;
    }
    instrs
}

/// The PR 4 TP-ISA hot path: reused simulator, per-instruction
/// `run_traced::<CyclesOnly>`.
fn interp_tpisa(model: &Model, prog: &TpIsaProgram, xs: &[Vec<f32>]) -> u64 {
    let mut sim = TpIsa::from_prepared(Arc::clone(&prog.prepared));
    for (si, x) in xs.iter().enumerate() {
        if si > 0 {
            sim.reset();
        }
        let words = harness::input_words_tpisa(model, prog, x).unwrap();
        sim.dmem.write_words(prog.input_base, &words).unwrap();
        let halt = sim.run_traced::<CyclesOnly>(500_000_000).unwrap();
        assert_eq!(halt, printed_bespoke::sim::tpisa::Halt::Halted);
    }
    sim.profile.instructions
}

/// One translated TP-ISA batch for the dynamic block/fallback counters.
fn translated_stats_tpisa(model: &Model, prog: &TpIsaProgram, xs: &[Vec<f32>]) -> (ExecStats, u64) {
    let mut sim = TpIsa::from_prepared(Arc::clone(&prog.prepared));
    for (si, x) in xs.iter().enumerate() {
        if si > 0 {
            sim.reset();
        }
        let words = harness::input_words_tpisa(model, prog, x).unwrap();
        sim.dmem.write_words(prog.input_base, &words).unwrap();
        let halt = sim.run_translated::<CyclesOnly>(500_000_000).unwrap();
        assert_eq!(halt, printed_bespoke::sim::tpisa::Halt::Halted);
    }
    (sim.exec_stats, sim.profile.instructions)
}

/// One batched TP-ISA lockstep pass for the divergence counters.
fn batched_stats_tpisa(model: &Model, prog: &TpIsaProgram, xs: &[Vec<f32>]) -> (ExecStats, u64) {
    let mut batch = BatchTpIsa::new(Arc::clone(&prog.prepared), xs.len());
    for (i, x) in xs.iter().enumerate() {
        let words = harness::input_words_tpisa(model, prog, x).unwrap();
        batch.lane_mut(i).dmem.write_words(prog.input_base, &words).unwrap();
    }
    for res in batch.run::<CyclesOnly>(xs.len(), 500_000_000) {
        assert_eq!(res.unwrap(), printed_bespoke::sim::tpisa::Halt::Halted);
    }
    let mut p = Profile::default();
    batch.fold_profile(&mut p);
    (batch.exec_stats(), p.instructions)
}

fn mips(instrs: u64, min_ms: f64) -> f64 {
    instrs as f64 / (min_ms / 1e3) / 1e6
}

fn main() -> anyhow::Result<()> {
    let ctx = EvalContext::load(32)?;
    // The largest MLP program plus one SVM: the straight-line-dominant
    // models the ≥2× translated-vs-interpreted gate applies to.
    let mut model_idx = vec![0usize];
    if let Some(svm) = ctx.models.iter().position(|m| m.name.starts_with("svm")) {
        model_idx.push(svm);
    }
    let mut rows: Vec<Row> = Vec::new();

    // Zero-Riscy ISS rate.
    for &mi in &model_idx {
        let model = &ctx.models[mi];
        let xs = &ctx.cycle_samples[mi];
        for variant in [Rv32Variant::Baseline, Rv32Variant::Simd(8)] {
            let prog = codegen_rv32::generate(model, variant)?;
            let label = variant.label();
            let name = format!("zr {} {label}", model.name);
            let mut instrs = 0u64;
            let r_legacy = bench(&format!("{name} legacy x{}", xs.len()), 1, 10, || {
                instrs = legacy_rv32(model, &prog, xs);
            });
            let m_legacy = mips(instrs, r_legacy.min_ms);
            let r_interp = bench(&format!("{name} interp cycles-only x{}", xs.len()), 1, 10, || {
                instrs = interp_rv32(model, &prog, xs);
            });
            let m_interp = mips(instrs, r_interp.min_ms);
            let r_full = bench(&format!("{name} translated full x{}", xs.len()), 1, 10, || {
                let run = harness::run_rv32_scalar_traced::<FullProfile>(model, &prog, xs).unwrap();
                instrs = run.profile.instructions;
            });
            let m_full = mips(instrs, r_full.min_ms);
            let r_trans = bench(&format!("{name} translated cycles-only x{}", xs.len()), 1, 10, || {
                let run = harness::run_rv32_scalar_traced::<CyclesOnly>(model, &prog, xs).unwrap();
                instrs = run.profile.instructions;
            });
            let m_trans = mips(instrs, r_trans.min_ms);
            let r_bfull = bench(&format!("{name} batched full x{}", xs.len()), 1, 10, || {
                let run = harness::run_rv32_batched::<FullProfile>(model, &prog, xs, BATCH).unwrap();
                instrs = run.profile.instructions;
            });
            let m_bfull = mips(instrs, r_bfull.min_ms);
            let r_batch = bench(&format!("{name} batched cycles-only x{}", xs.len()), 1, 10, || {
                let run = harness::run_rv32_batched::<CyclesOnly>(model, &prog, xs, BATCH).unwrap();
                instrs = run.profile.instructions;
            });
            let m_batch = mips(instrs, r_batch.min_ms);
            let (dyn_stats, dyn_instrs) = translated_stats_rv32(model, &prog, xs);
            let (b_stats, b_instrs) = batched_stats_rv32(model, &prog, xs);
            let st = prog.translate_stats();
            println!(
                "{:<44} legacy {m_legacy:.2} | interp {m_interp:.2} | translated {m_trans:.2} | \
                 batched {m_batch:.2} MIPS (x{:.2} vs interp, x{:.2} vs translated)",
                format!("  -> {name}"),
                m_trans / m_interp,
                m_batch / m_trans
            );
            rows.push(Row {
                core: "zero-riscy",
                model: model.name.clone(),
                variant: label,
                samples: xs.len(),
                mips_legacy: m_legacy,
                mips_interp: m_interp,
                mips_full: m_full,
                mips_translated: m_trans,
                mips_batched_full: m_bfull,
                mips_batched_cycles_only: m_batch,
                batch_size: BATCH.min(xs.len()),
                blocks: st.blocks,
                fused: st.fused,
                static_coverage: st.translated_instructions as f64 / st.instructions.max(1) as f64,
                fallback_rate: dyn_stats.fallback_instrs as f64 / dyn_instrs.max(1) as f64,
                divergence_rate: b_stats.fallback_instrs as f64 / b_instrs.max(1) as f64,
            });
        }
    }

    // TP-ISA ISS rate (software-multiply baseline is the heavy one).
    for &mi in &model_idx {
        let model = &ctx.models[mi];
        let xs = &ctx.cycle_samples[mi];
        for (d, variant) in [(8u32, TpVariant::Baseline), (8, TpVariant::Mac { precision: 8 })] {
            let Ok(prog) = codegen_tpisa::generate(model, d, variant) else {
                continue;
            };
            let label = format!("d{d} {}", variant.label());
            let name = format!("tp {} {label}", model.name);
            let mut instrs = 0u64;
            let r_legacy = bench(&format!("{name} legacy x{}", xs.len()), 1, 5, || {
                instrs = legacy_tpisa(model, &prog, xs);
            });
            let m_legacy = mips(instrs, r_legacy.min_ms);
            let r_interp = bench(&format!("{name} interp cycles-only x{}", xs.len()), 1, 5, || {
                instrs = interp_tpisa(model, &prog, xs);
            });
            let m_interp = mips(instrs, r_interp.min_ms);
            let r_full = bench(&format!("{name} translated full x{}", xs.len()), 1, 5, || {
                let run =
                    harness::run_tpisa_scalar_traced::<FullProfile>(model, &prog, xs).unwrap();
                instrs = run.profile.instructions;
            });
            let m_full = mips(instrs, r_full.min_ms);
            let r_trans = bench(&format!("{name} translated cycles-only x{}", xs.len()), 1, 5, || {
                let run =
                    harness::run_tpisa_scalar_traced::<CyclesOnly>(model, &prog, xs).unwrap();
                instrs = run.profile.instructions;
            });
            let m_trans = mips(instrs, r_trans.min_ms);
            let r_bfull = bench(&format!("{name} batched full x{}", xs.len()), 1, 5, || {
                let run =
                    harness::run_tpisa_batched::<FullProfile>(model, &prog, xs, BATCH).unwrap();
                instrs = run.profile.instructions;
            });
            let m_bfull = mips(instrs, r_bfull.min_ms);
            let r_batch = bench(&format!("{name} batched cycles-only x{}", xs.len()), 1, 5, || {
                let run =
                    harness::run_tpisa_batched::<CyclesOnly>(model, &prog, xs, BATCH).unwrap();
                instrs = run.profile.instructions;
            });
            let m_batch = mips(instrs, r_batch.min_ms);
            let (dyn_stats, dyn_instrs) = translated_stats_tpisa(model, &prog, xs);
            let (b_stats, b_instrs) = batched_stats_tpisa(model, &prog, xs);
            let st = prog.translate_stats();
            println!(
                "{:<44} legacy {m_legacy:.2} | interp {m_interp:.2} | translated {m_trans:.2} | \
                 batched {m_batch:.2} MIPS (x{:.2} vs interp, x{:.2} vs translated)",
                format!("  -> {name}"),
                m_trans / m_interp,
                m_batch / m_trans
            );
            rows.push(Row {
                core: "tp-isa",
                model: model.name.clone(),
                variant: label,
                samples: xs.len(),
                mips_legacy: m_legacy,
                mips_interp: m_interp,
                mips_full: m_full,
                mips_translated: m_trans,
                mips_batched_full: m_bfull,
                mips_batched_cycles_only: m_batch,
                batch_size: BATCH.min(xs.len()),
                blocks: st.blocks,
                fused: st.fused,
                static_coverage: st.translated_instructions as f64 / st.instructions.max(1) as f64,
                fallback_rate: dyn_stats.fallback_instrs as f64 / dyn_instrs.max(1) as f64,
                divergence_rate: b_stats.fallback_instrs as f64 / b_instrs.max(1) as f64,
            });
        }
    }

    // Archive the before/after numbers.
    let mut json = String::new();
    json.push_str("{\n  \"bench\": \"perf_iss\",\n  \"unit\": \"MIPS\",\n  \"results\": [\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"core\": \"{}\", \"model\": \"{}\", \"variant\": \"{}\", \"samples\": {}, \
             \"mips_legacy\": {:.3}, \"mips_interp_cycles_only\": {:.3}, \
             \"mips_translated_full\": {:.3}, \"mips_translated_cycles_only\": {:.3}, \
             \"mips_batched_full\": {:.3}, \"mips_batched_cycles_only\": {:.3}, \
             \"speedup_translated_vs_interp\": {:.3}, \"speedup_vs_legacy\": {:.3}, \
             \"speedup_batched_vs_translated\": {:.3}, \"batch_size\": {}, \
             \"blocks\": {}, \"fused_superinstructions\": {}, \"static_coverage\": {:.4}, \
             \"fallback_rate\": {:.6}, \"divergence_rate\": {:.6}}}{}\n",
            r.core,
            r.model,
            r.variant,
            r.samples,
            r.mips_legacy,
            r.mips_interp,
            r.mips_full,
            r.mips_translated,
            r.mips_batched_full,
            r.mips_batched_cycles_only,
            r.mips_translated / r.mips_interp,
            r.mips_translated / r.mips_legacy,
            r.mips_batched_cycles_only / r.mips_translated,
            r.batch_size,
            r.blocks,
            r.fused,
            r.static_coverage,
            r.fallback_rate,
            r.divergence_rate,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    // cargo runs bench binaries with cwd = the package root (rust/);
    // anchor the emission at the workspace root, where CI picks it up.
    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_iss.json");
    std::fs::write(&out, &json)?;
    println!("wrote {} ({} configurations)", out.display(), rows.len());
    Ok(())
}
