//! Perf bench (L3 hot path): ISS simulation rate in instructions/second
//! for both cores, plus per-sample inference cost per variant.  Used by
//! the EXPERIMENTS.md §Perf iteration log.

use printed_bespoke::dse::context::EvalContext;
use printed_bespoke::ml::codegen_rv32::{self, Rv32Variant};
use printed_bespoke::ml::codegen_tpisa::{self, TpVariant};
use printed_bespoke::ml::harness;
use printed_bespoke::util::bench::bench;

fn main() -> anyhow::Result<()> {
    let ctx = EvalContext::load(32)?;
    let model = &ctx.models[0]; // mlp_c_cardio: the largest program
    let xs = &ctx.cycle_samples[0];

    // Zero-Riscy ISS rate.
    for variant in [Rv32Variant::Baseline, Rv32Variant::Simd(8)] {
        let prog = codegen_rv32::generate(model, variant)?;
        let mut instrs = 0u64;
        let r = bench(&format!("zero-riscy ISS {} x{}", variant.label(), xs.len()), 1, 10, || {
            let run = harness::run_rv32(model, &prog, xs).unwrap();
            instrs = run.profile.instructions;
        });
        let ips = instrs as f64 / (r.min_ms / 1e3);
        println!("{:<40} {:>12.2} M instr/s", format!("  -> {}", variant.label()), ips / 1e6);
    }

    // TP-ISA ISS rate (software-multiply baseline is the heavy one).
    for (d, variant) in [(8u32, TpVariant::Baseline), (8, TpVariant::Mac { precision: 8 })] {
        let prog = codegen_tpisa::generate(model, d, variant)?;
        let mut instrs = 0u64;
        let r = bench(&format!("tp-isa d{d} ISS {} x{}", variant.label(), xs.len()), 1, 5, || {
            let run = harness::run_tpisa(model, &prog, xs).unwrap();
            instrs = run.profile.instructions;
        });
        let ips = instrs as f64 / (r.min_ms / 1e3);
        println!("{:<40} {:>12.2} M instr/s", format!("  -> {}", variant.label()), ips / 1e6);
    }
    Ok(())
}
