//! Perf bench (L3 hot path): ISS simulation rate in instructions/second
//! (MIPS) per (core, variant), in three configurations:
//!
//! * `legacy`      — the pre-rework per-sample path (fresh simulator per
//!   sample: program re-encode, RAM/dmem realloc, per-byte/word
//!   preloads, full profiling) — the *before* number;
//! * `full`        — reused simulator + prepared image, `FullProfile`;
//! * `cycles-only` — reused simulator + `CyclesOnly` tracer: the path
//!   the DSE sweeps, crosscheck and accuracy runs actually take.
//!
//! Emits `BENCH_iss.json` with every number so CI can archive the
//! before/after trajectory.  The `->` summary lines report the
//! cycles-only MIPS (the production hot path).

use printed_bespoke::dse::context::EvalContext;
use printed_bespoke::ml::codegen_rv32::{
    self, InputFormat, Rv32Program, Rv32Variant, INPUT_OFF, RAM_BYTES, SCORES_OFF,
};
use printed_bespoke::ml::codegen_tpisa::{self, TpIsaProgram, TpVariant};
use printed_bespoke::ml::harness;
use printed_bespoke::ml::model::Model;
use printed_bespoke::ml::quant::{pack_vec, quantize};
use printed_bespoke::sim::mem::RAM_BASE;
use printed_bespoke::sim::tpisa::TpIsa;
use printed_bespoke::sim::trace::CyclesOnly;
use printed_bespoke::sim::zero_riscy::{Halt, ZeroRiscy};
use printed_bespoke::util::bench::bench;

struct Row {
    core: &'static str,
    variant: String,
    samples: usize,
    mips_legacy: f64,
    mips_full: f64,
    mips_cycles_only: f64,
}

/// The pre-rework RV32 harness: fresh simulator + per-byte preload per
/// sample.  Returns retired instructions (for the MIPS denominator).
fn legacy_rv32(model: &Model, prog: &Rv32Program, xs: &[Vec<f32>]) -> u64 {
    let p = prog.variant.quant_precision();
    let fx = model.qlayers(p).unwrap()[0].fx;
    let mut instrs = 0u64;
    for x in xs {
        let mut sim =
            ZeroRiscy::new(&prog.code, &prog.rom_data, RAM_BYTES, prog.variant.mac_config());
        let qx: Vec<i64> = x.iter().map(|&v| quantize(v as f64, fx, p)).collect();
        let mut input = Vec::new();
        match prog.input_format {
            InputFormat::I16 => {
                for q in qx {
                    input.extend_from_slice(&(q as i16).to_le_bytes());
                }
            }
            InputFormat::Packed(prec) => {
                for w in pack_vec(&qx, prec, 32) {
                    input.extend_from_slice(&(w as u32).to_le_bytes());
                }
            }
        }
        for (i, b) in input.iter().enumerate() {
            sim.mem.store_u8(RAM_BASE + INPUT_OFF as u32 + i as u32, *b).unwrap();
        }
        assert_eq!(sim.run(50_000_000).unwrap(), Halt::Break);
        let mut raw = Vec::with_capacity(prog.n_scores);
        for j in 0..prog.n_scores {
            let addr = RAM_BASE + SCORES_OFF as u32 + 4 * j as u32;
            let acc = sim.mem.load_u32(addr).unwrap() as i32 as i64;
            raw.push(acc as f64 / prog.score_scale);
        }
        let s = model.head_scores(&raw);
        std::hint::black_box(model.predict(&s));
        instrs += sim.profile.instructions;
    }
    instrs
}

/// The pre-rework TP-ISA harness: fresh simulator + per-word constant
/// and input preload per sample.
fn legacy_tpisa(model: &Model, prog: &TpIsaProgram, xs: &[Vec<f32>]) -> u64 {
    let p = prog.quant_precision;
    let fx = model.qlayers(p).unwrap()[0].fx;
    let mut instrs = 0u64;
    for x in xs {
        let mut sim = TpIsa::new(prog.datapath, &prog.code, prog.dmem_words, prog.mac_config());
        for (addr, v) in prog.dmem_image.iter().enumerate() {
            sim.dmem.store(addr as i64, *v).unwrap();
        }
        let qx: Vec<i64> = x.iter().map(|&v| quantize(v as f64, fx, p)).collect();
        let words: Vec<u64> = if prog.packed_input {
            pack_vec(&qx, p, prog.datapath)
        } else {
            qx.iter().map(|&q| q as u64).collect()
        };
        for (i, w) in words.iter().enumerate() {
            sim.dmem.store(prog.input_base as i64 + i as i64, *w).unwrap();
        }
        let halt = sim.run(500_000_000).unwrap();
        assert_eq!(halt, printed_bespoke::sim::tpisa::Halt::Halted);
        let nacc = (32 / prog.datapath).max(1) as usize;
        let mut raw = Vec::with_capacity(prog.n_scores);
        for j in 0..prog.n_scores {
            let mut acc: u64 = 0;
            for wi in 0..nacc {
                let chunk = sim.dmem.load((prog.score_base + j * nacc + wi) as i64).unwrap();
                acc |= chunk << (prog.datapath * wi as u32);
            }
            let acc = printed_bespoke::sim::mac_model::sext(acc, 32);
            raw.push(acc as f64 / prog.score_scale);
        }
        let s = model.head_scores(&raw);
        std::hint::black_box(model.predict(&s));
        instrs += sim.profile.instructions;
    }
    instrs
}

fn mips(instrs: u64, min_ms: f64) -> f64 {
    instrs as f64 / (min_ms / 1e3) / 1e6
}

fn main() -> anyhow::Result<()> {
    let ctx = EvalContext::load(32)?;
    let model = &ctx.models[0]; // mlp_c_cardio: the largest program
    let xs = &ctx.cycle_samples[0];
    let mut rows: Vec<Row> = Vec::new();

    // Zero-Riscy ISS rate.
    for variant in [Rv32Variant::Baseline, Rv32Variant::Simd(8)] {
        let prog = codegen_rv32::generate(model, variant)?;
        let label = variant.label();
        let mut instrs = 0u64;
        let r_legacy = bench(&format!("zr {label} legacy fresh-sim x{}", xs.len()), 1, 10, || {
            instrs = legacy_rv32(model, &prog, xs);
        });
        let m_legacy = mips(instrs, r_legacy.min_ms);
        let r_full = bench(&format!("zr {label} reused full-profile x{}", xs.len()), 1, 10, || {
            let run = harness::run_rv32(model, &prog, xs).unwrap();
            instrs = run.profile.instructions;
        });
        let m_full = mips(instrs, r_full.min_ms);
        let r_cyc = bench(&format!("zr {label} reused cycles-only x{}", xs.len()), 1, 10, || {
            let run = harness::run_rv32_traced::<CyclesOnly>(model, &prog, xs).unwrap();
            instrs = run.profile.instructions;
        });
        let m_cyc = mips(instrs, r_cyc.min_ms);
        println!("{:<40} {:>12.2} M instr/s", format!("  -> {label}"), m_cyc);
        println!(
            "{:<40} legacy {m_legacy:.2} | full {m_full:.2} | cycles-only {m_cyc:.2} MIPS \
             (x{:.2} vs legacy)",
            format!("     {label}"),
            m_cyc / m_legacy
        );
        rows.push(Row {
            core: "zero-riscy",
            variant: label,
            samples: xs.len(),
            mips_legacy: m_legacy,
            mips_full: m_full,
            mips_cycles_only: m_cyc,
        });
    }

    // TP-ISA ISS rate (software-multiply baseline is the heavy one).
    for (d, variant) in [(8u32, TpVariant::Baseline), (8, TpVariant::Mac { precision: 8 })] {
        let prog = codegen_tpisa::generate(model, d, variant)?;
        let label = format!("d{d} {}", variant.label());
        let mut instrs = 0u64;
        let r_legacy = bench(&format!("tp {label} legacy fresh-sim x{}", xs.len()), 1, 5, || {
            instrs = legacy_tpisa(model, &prog, xs);
        });
        let m_legacy = mips(instrs, r_legacy.min_ms);
        let r_full = bench(&format!("tp {label} reused full-profile x{}", xs.len()), 1, 5, || {
            let run = harness::run_tpisa(model, &prog, xs).unwrap();
            instrs = run.profile.instructions;
        });
        let m_full = mips(instrs, r_full.min_ms);
        let r_cyc = bench(&format!("tp {label} reused cycles-only x{}", xs.len()), 1, 5, || {
            let run = harness::run_tpisa_traced::<CyclesOnly>(model, &prog, xs).unwrap();
            instrs = run.profile.instructions;
        });
        let m_cyc = mips(instrs, r_cyc.min_ms);
        println!("{:<40} {:>12.2} M instr/s", format!("  -> {label}"), m_cyc);
        println!(
            "{:<40} legacy {m_legacy:.2} | full {m_full:.2} | cycles-only {m_cyc:.2} MIPS \
             (x{:.2} vs legacy)",
            format!("     {label}"),
            m_cyc / m_legacy
        );
        rows.push(Row {
            core: "tp-isa",
            variant: label,
            samples: xs.len(),
            mips_legacy: m_legacy,
            mips_full: m_full,
            mips_cycles_only: m_cyc,
        });
    }

    // Archive the before/after numbers.
    let mut json = String::new();
    json.push_str("{\n  \"bench\": \"perf_iss\",\n  \"unit\": \"MIPS\",\n  \"results\": [\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"core\": \"{}\", \"variant\": \"{}\", \"samples\": {}, \
             \"mips_legacy\": {:.3}, \"mips_full\": {:.3}, \"mips_cycles_only\": {:.3}, \
             \"speedup_vs_legacy\": {:.3}}}{}\n",
            r.core,
            r.variant,
            r.samples,
            r.mips_legacy,
            r.mips_full,
            r.mips_cycles_only,
            r.mips_cycles_only / r.mips_legacy,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    // cargo runs bench binaries with cwd = the package root (rust/);
    // anchor the emission at the workspace root, where CI picks it up.
    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_iss.json");
    std::fs::write(&out, &json)?;
    println!("wrote {} ({} configurations)", out.display(), rows.len());
    Ok(())
}
