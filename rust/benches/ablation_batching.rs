//! Ablation: the coordinator's dynamic-batching policy (DESIGN.md
//! design choice).  Sweeps the batcher's max_batch against a fixed
//! streamed load and reports throughput + latency, demonstrating (a)
//! why the batcher exists at all (tiny batches pay the fixed 256-sample
//! executable cost per flush) and (b) why max_batch is aligned to the
//! executable batch (§Perf iteration 3).

use std::time::Instant;

use anyhow::{anyhow, Context};
use printed_bespoke::coordinator::router::Key;
use printed_bespoke::coordinator::service::{Service, ServiceConfig};
use printed_bespoke::ml::dataset::Dataset;
use printed_bespoke::util::stats;

fn main() -> anyhow::Result<()> {
    println!("max_batch   throughput [req/s]   p50 [ms]   p99 [ms]   mean batch");
    let mut rows = Vec::new();
    for max_batch in [1usize, 8, 32, 64, 128, 256] {
        let cfg = ServiceConfig { max_batch, linger_ms: 1, ..ServiceConfig::default() };
        let svc = Service::start(cfg)?;
        let model = svc.models[0].clone();
        let ds = Dataset::load(svc.manifest.data_dir(), &model.dataset, "test")?;
        let key = Key::precision(&model.name, 8);
        let xs: Vec<Vec<f32>> = ds.x.iter().take(512).cloned().collect();
        // Warm-up compile.
        svc.scores(&key, &xs[..1])?;

        let mut lat = Vec::new();
        let t0 = Instant::now();
        for _round in 0..3 {
            let pending: Vec<_> = xs
                .iter()
                .map(|x| (Instant::now(), svc.submit(key.clone(), x.clone()).unwrap()))
                .collect();
            for (t, rx) in pending {
                rx.recv().context("reply")?.map_err(|e| anyhow!(e))?;
                lat.push(t.elapsed().as_secs_f64() * 1e3);
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let tput = (3 * xs.len()) as f64 / wall;
        let s = stats::summarize(&lat);
        let mb = svc.metrics.lock().unwrap().mean_batch_size();
        println!(
            "{max_batch:>9}   {tput:>18.0}   {:>8.3}   {:>8.3}   {mb:>10.1}",
            s.p50, s.p99
        );
        rows.push((max_batch, tput));
    }
    // The ablation's claim: batching wins by a wide margin over
    // batch=1, and large batches (>=128) beat small ones (<=8).
    let t1 = rows.iter().find(|(b, _)| *b == 1).unwrap().1;
    let t8 = rows.iter().find(|(b, _)| *b == 8).unwrap().1;
    let t256 = rows.iter().find(|(b, _)| *b == 256).unwrap().1;
    assert!(t256 > 2.0 * t1, "batching must win big: {t256} vs {t1}");
    assert!(t256 > t8, "aligned batches must beat small ones");
    println!("ablation: batching policy justified (x{:.1} over batch=1)", t256 / t1);
    Ok(())
}
