//! Bench: regenerate paper Table I (bespoke Zero-Riscy area/power gains,
//! average speedup and accuracy loss across the six ML models) and
//! verify the paper's orderings hold.

use printed_bespoke::dse::context::EvalContext;
use printed_bespoke::dse::report;
use printed_bespoke::util::bench::bench;

fn main() -> anyhow::Result<()> {
    let ctx = EvalContext::load(8)?;
    let t = report::table1(&ctx)?;
    println!("{}", t.text);

    let get = |name: &str| t.rows.iter().find(|r| r.name == name).unwrap();
    let (b, m32, p16, p8, p4) = (
        get("ZR B"),
        get("ZR B MAC 32"),
        get("ZR B MAC P16"),
        get("ZR B MAC P8"),
        get("ZR B MAC P4"),
    );
    // Paper Table I shape: MAC32 area gain dips below B; P16 < P8 < P4
    // in gains; speedups strictly increasing; accuracy loss jumps at P4.
    assert!(m32.area_gain_pct < b.area_gain_pct);
    assert!(p16.area_gain_pct > b.area_gain_pct);
    assert!(p8.area_gain_pct > p16.area_gain_pct);
    assert!(p4.area_gain_pct > p8.area_gain_pct);
    assert!(b.speedup_pct.abs() < 1.0);
    assert!(m32.speedup_pct > 5.0);
    assert!(p16.speedup_pct > m32.speedup_pct);
    assert!(p8.speedup_pct > p16.speedup_pct);
    assert!(p4.speedup_pct > p8.speedup_pct);
    assert!(p4.acc_loss_pct > p8.acc_loss_pct + 1.0);
    assert!(p16.acc_loss_pct < 0.5);
    println!("Table I orderings: OK");

    bench("zr_table1 sweep (6 models x 5 variants)", 0, 3, || {
        std::hint::black_box(report::table1(&ctx).unwrap());
    });
    Ok(())
}
