//! Bench: regenerate paper Table I (bespoke Zero-Riscy area/power gains,
//! average speedup and accuracy loss across the six ML models) and
//! verify the paper's orderings hold — then time the sweep at
//! `threads = 1` vs `threads >= 4` to show the parallel evaluation
//! engine's wall-clock win (the results themselves are bit-identical;
//! see `tests/parallel_determinism.rs`).

use printed_bespoke::dse::context::EvalContext;
use printed_bespoke::dse::report;
use printed_bespoke::util::bench::bench;

fn main() -> anyhow::Result<()> {
    let ctx = EvalContext::load(8)?;
    let t = report::table1(&ctx)?;
    println!("{}", t.text);

    let get = |name: &str| t.rows.iter().find(|r| r.name == name).unwrap();
    let (b, m32, p16, p8, p4) = (
        get("ZR B"),
        get("ZR B MAC 32"),
        get("ZR B MAC P16"),
        get("ZR B MAC P8"),
        get("ZR B MAC P4"),
    );
    // Paper Table I shape: MAC32 area gain dips below B; P16 < P8 < P4
    // in gains; speedups strictly increasing; accuracy loss jumps at P4.
    assert!(m32.area_gain_pct < b.area_gain_pct);
    assert!(p16.area_gain_pct > b.area_gain_pct);
    assert!(p8.area_gain_pct > p16.area_gain_pct);
    assert!(p4.area_gain_pct > p8.area_gain_pct);
    assert!(b.speedup_pct.abs() < 1.0);
    assert!(m32.speedup_pct > 5.0);
    assert!(p16.speedup_pct > m32.speedup_pct);
    assert!(p8.speedup_pct > p16.speedup_pct);
    assert!(p4.speedup_pct > p8.speedup_pct);
    assert!(p4.acc_loss_pct > p8.acc_loss_pct + 1.0);
    assert!(p16.acc_loss_pct < 0.5);
    println!("Table I orderings: OK");

    // Wall clock: the same sweep, sequential vs parallel.  Warmup = 1
    // so the per-context program caches are filled before timing.  The
    // already-loaded ctx doubles as the parallel context when it has
    // enough workers.
    let seq_ctx = EvalContext::load_with_threads(8, 1)?;
    let seq = bench("zr_table1 sweep (threads=1)", 1, 3, || {
        std::hint::black_box(report::table1(&seq_ctx).unwrap());
    });
    let wide_ctx;
    let par_ctx = if ctx.threads >= 4 {
        &ctx
    } else {
        wide_ctx = EvalContext::load_with_threads(8, 4)?;
        &wide_ctx
    };
    let threads = par_ctx.threads;
    let par = bench(&format!("zr_table1 sweep (threads={threads})"), 1, 3, || {
        std::hint::black_box(report::table1(par_ctx).unwrap());
    });
    println!(
        "parallel sweep speedup: x{:.2} (threads=1 -> threads={threads}, best-of-3)",
        seq.min_ms / par.min_ms
    );
    Ok(())
}
