//! Perf bench (L3 serving path): PJRT executable throughput, coordinator
//! bulk overhead, and streaming (router + dynamic batcher) throughput.
//! The coordinator target: within 1.5x of raw PJRT execute; max_batch aligned to the 256-sample executable batch (padding waste otherwise) (DESIGN.md
//! §Perf).

use anyhow::{anyhow, Context};
use printed_bespoke::coordinator::router::Key;
use printed_bespoke::coordinator::service::{Service, ServiceConfig};
use printed_bespoke::ml::dataset::Dataset;
use printed_bespoke::util::bench::{bench, bench_throughput};

fn main() -> anyhow::Result<()> {
    let cfg = ServiceConfig { max_batch: 256, linger_ms: 1, ..ServiceConfig::default() };
    let svc = Service::start(cfg)?;
    let model = svc.models[0].clone();
    let ds = Dataset::load(svc.manifest.data_dir(), &model.dataset, "test")?;
    let key = Key::precision(&model.name, 8);
    let xs: Vec<Vec<f32>> = ds.x.iter().take(512).cloned().collect();

    // Warm-up compile.
    svc.scores(&key, &xs[..1])?;

    // Bulk path: full batches through the coordinator.
    let bulk = bench_throughput("coordinator bulk 512 samples (p8)", xs.len(), 1, 10, || {
        std::hint::black_box(svc.scores(&key, &xs).unwrap());
    });

    // Streaming path: single-sample requests through router + batcher.
    let stream = bench_throughput("coordinator streaming 512 reqs (p8)", xs.len(), 1, 5, || {
        let pending: Vec<_> = xs
            .iter()
            .map(|x| svc.submit(key.clone(), x.clone()).unwrap())
            .collect();
        for rx in pending {
            rx.recv().context("reply").unwrap().map_err(|e| anyhow!(e)).unwrap();
        }
    });

    bench("single-sample round trip (p8)", 5, 50, || {
        let rx = svc.submit(key.clone(), xs[0].clone()).unwrap();
        rx.recv().unwrap().unwrap();
    });

    println!(
        "\nstreaming/bulk throughput ratio: {:.2} (target: batching amortises \
         the per-request overhead to >= 0.3x bulk)",
        stream / bulk
    );
    println!("metrics: {}", svc.metrics.lock().unwrap().summary());
    Ok(())
}
