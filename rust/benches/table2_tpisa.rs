//! Bench: regenerate paper Table II (the bespoke 8-bit TP-ISA MAC
//! Pareto solution) and check its factors against the paper's bands.

use printed_bespoke::dse::context::EvalContext;
use printed_bespoke::dse::report;
use printed_bespoke::util::bench::bench;

fn main() -> anyhow::Result<()> {
    let ctx = EvalContext::load(8)?;
    let t = report::table2(&ctx)?;
    println!("{}", t.text);

    // Paper: x1.98 area, x1.82 power, 0.5% err, up to 85.1% speedup.
    // Bands: same winner, same rough factors.
    assert!((1.4..=2.6).contains(&t.area_factor), "area factor {}", t.area_factor);
    assert!((1.4..=2.6).contains(&t.power_factor), "power factor {}", t.power_factor);
    assert!(t.speedup_pct > 60.0, "speedup {}", t.speedup_pct);
    assert!(t.err_pct < 2.0, "err {}", t.err_pct);
    println!("Table II bands: OK");

    bench(&format!("table2 (d8 sweep pair, threads={})", ctx.threads), 0, 3, || {
        std::hint::black_box(report::table2(&ctx).unwrap());
    });
    Ok(())
}
