//! Bench: regenerate paper Fig. 5 (TP-ISA configuration scatter with
//! the area-speedup Pareto front) and verify its structure.

use printed_bespoke::dse::context::EvalContext;
use printed_bespoke::dse::report;
use printed_bespoke::util::bench::bench;

fn main() -> anyhow::Result<()> {
    let ctx = EvalContext::load(6)?;
    let f = report::fig5(&ctx)?;
    println!("{}", f.text);

    // Paper: "The lower-left group of points corresponds to the baseline
    // cores, achieving no speedup, while the upper-side implementations
    // are generated through the proposed methodology."
    for p in &f.points {
        if matches!(p.variant, printed_bespoke::ml::codegen_tpisa::TpVariant::Baseline) {
            assert!(p.speedup_pct.abs() < 1.0, "{}: baseline must have ~0 speedup", p.label);
        } else {
            assert!(p.speedup_pct > 50.0, "{}: MAC configs speed up sharply", p.label);
        }
    }
    // The front is non-trivial: at least 3 points, containing both a
    // cheap baseline and a high-speedup MAC config.
    let front: Vec<&str> = f
        .points
        .iter()
        .zip(&f.pareto)
        .filter(|(_, &on)| on)
        .map(|(p, _)| p.label.as_str())
        .collect();
    println!("Pareto front: {front:?}");
    assert!(front.len() >= 3);
    assert!(front.iter().any(|l| !l.contains('m')));
    assert!(front.iter().any(|l| l.contains('m')));
    println!("Fig 5 structure: OK");

    bench(&format!("tpisa_sweep (14 configs x 6 models, threads={})", ctx.threads), 0, 3, || {
        std::hint::black_box(report::fig5(&ctx).unwrap());
    });
    Ok(())
}
