//! Bench: regenerate paper Fig. 4 (average accuracy loss per model per
//! precision option) and verify the shape: no loss at 32/16 bits, small
//! loss at 8, a jump at 4 with the wine models worst.

use printed_bespoke::dse::context::EvalContext;
use printed_bespoke::dse::report;
use printed_bespoke::util::bench::bench;
use printed_bespoke::util::stats;

fn main() -> anyhow::Result<()> {
    let ctx = EvalContext::load(4)?;
    let f = report::fig4(&ctx);
    println!("{}", f.text);

    let col = |i: usize| -> Vec<f64> { f.losses.iter().map(|(_, r)| r[i]).collect() };
    let (l32, l16, l8, l4) = (col(0), col(1), col(2), col(3));
    assert!(stats::mean(&l32).abs() < 0.05, "p32 must be lossless");
    assert!(stats::mean(&l16).abs() < 0.5, "p16 ~ lossless");
    assert!(stats::mean(&l8) < 2.0, "p8 small loss");
    assert!(
        stats::mean(&l4) > stats::mean(&l8) + 2.0,
        "p4 must jump (paper: up to 26% on RedWine)"
    );
    // The worst p4 model is a wine model (paper: RedWine).
    let worst = f
        .losses
        .iter()
        .max_by(|a, b| a.1[3].partial_cmp(&b.1[3]).unwrap())
        .unwrap();
    println!("worst p4 model: {} ({:.2}%)", worst.0, worst.1[3]);
    assert!(worst.0.contains("wine"));
    println!("Fig 4 shape: OK");

    bench("fig4 (manifest accuracy matrix)", 1, 50, || {
        std::hint::black_box(report::fig4(&ctx));
    });
    Ok(())
}
